//! Property and integration tests for the flight recorder (`ripple::obs`).
//!
//! The recorder's contract is accounting, not sampling: every span folds
//! into the per-phase aggregate even when the retention ring overflows, and
//! the phase sums close against the simulator's own metric totals
//! *bit-for-bit*, because both sides accumulate the same `f64` values in
//! the same order starting from `0.0`. These tests pin that contract:
//!
//! 1. Recorder-level closure: tokens driven with `latency := accounted`
//!    close exactly, and phase sums match a shadow accumulator bitwise.
//! 2. Ring overflow: oldest entries are overwritten, the drop counter is
//!    exact, retained contents are the newest suffix in order, and the
//!    aggregate still counts everything.
//! 3. Tail sampling: the slowest-K reservoir is deterministic and matches
//!    a brute-force top-K.
//! 4. Flash integration: Σ `FlashService` span durations equals
//!    `FlashStats::total_busy_ns` bitwise, and submit/complete/drop marks
//!    count batches exactly.
//! 5. Serve integration: with a recorder attached, Σ `FlashQueue` ==
//!    `RunMetrics.totals.stall_ns` and Σ `Compute` == `RunMetrics.compute_ns`
//!    bitwise, and the Chrome trace export is bit-identical across runs.

use ripple::bench::workloads::{tiny_workload, System, SystemSpec};
use ripple::coordinator::{run_serve_traced, ServeConfig, ServeOutcome};
use ripple::flash::{ReadCmd, UfsSim};
use ripple::obs::export::{chrome_trace_json, validate_chrome_trace};
use ripple::obs::{
    FlightRecorder, MarkKind, Phase, Ring, TailSampler, TokenChain, TraceConfig, TraceHandle,
    Track,
};

/// Deterministic 64-bit LCG (Knuth MMIX constants) for generating test
/// durations without `rand`.
struct Lcg(u64);

impl Lcg {
    fn new(seed: u64) -> Self {
        Lcg(seed)
    }

    fn next_u64(&mut self) -> u64 {
        self.0 = self
            .0
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.0
    }

    /// Uniform duration in `[0, scale_ns)`.
    fn dur(&mut self, scale_ns: f64) -> f64 {
        let u = (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
        u * scale_ns
    }
}

// -----------------------------------------------------------------------
// 1. Recorder-level closure
// -----------------------------------------------------------------------

#[test]
fn constructed_tokens_close_bit_for_bit() {
    let mut rec = FlightRecorder::new(TraceConfig::default());
    let mut rng = Lcg::new(0x0b5e_7a11);
    let n = 500u64;
    // Shadow accumulators mirror exactly what the aggregate should hold.
    let (mut sum_q, mut sum_s, mut sum_c) = (0.0f64, 0.0f64, 0.0f64);
    let mut start = 0.0f64;
    for i in 0..n {
        let q = rng.dur(5e4);
        let s = rng.dur(2e5);
        let c = rng.dur(1e5);
        // The producer reports latency == the recorder's own phase-sum
        // expression, so every token must close exactly.
        let latency = (q + s) + c;
        rec.token((i % 7) as u32, start, q, s, c, latency);
        sum_q += q;
        sum_s += s;
        sum_c += c;
        start += latency;
    }

    let agg = rec.aggregate();
    assert_eq!(agg.tokens(), n);
    assert_eq!(agg.exact_closures(), n, "latency := accounted must close every token");
    assert_eq!(
        agg.accounted_ns().to_bits(),
        agg.latency_ns().to_bits(),
        "aggregate accounted and latency sums must agree bitwise"
    );
    for p in [Phase::RoundQueue, Phase::FlashQueue, Phase::Compute] {
        assert_eq!(agg.phase_count(p), n);
    }
    assert_eq!(agg.phase_total_ns(Phase::RoundQueue).to_bits(), sum_q.to_bits());
    assert_eq!(agg.phase_total_ns(Phase::FlashQueue).to_bits(), sum_s.to_bits());
    assert_eq!(agg.phase_total_ns(Phase::Compute).to_bits(), sum_c.to_bits());
    // token() emits three spans + one TokenDone mark per token.
    assert_eq!(rec.spans_len() as u64 + rec.spans_dropped(), 3 * n);
    assert_eq!(
        rec.marks().filter(|m| m.kind == MarkKind::TokenDone).count() as u64,
        n
    );
}

// -----------------------------------------------------------------------
// 2. Ring overflow
// -----------------------------------------------------------------------

#[test]
fn ring_overwrites_oldest_and_counts_drops() {
    let cap = 64usize;
    let total = 200u64;
    let mut ring: Ring<u64> = Ring::new(cap);
    for i in 0..total {
        ring.push(i);
    }
    assert_eq!(ring.len(), cap);
    assert_eq!(ring.len() as u64 + ring.dropped(), total);
    // Retained contents are exactly the newest suffix, oldest to newest.
    let got: Vec<u64> = ring.iter().copied().collect();
    let want: Vec<u64> = (total - cap as u64..total).collect();
    assert_eq!(got, want);
}

#[test]
fn aggregate_survives_span_ring_overflow() {
    let cfg = TraceConfig {
        span_capacity: 32,
        mark_capacity: 16,
        ..TraceConfig::default()
    };
    let mut rec = FlightRecorder::new(cfg);
    let mut rng = Lcg::new(0xdead_beef);
    let n = 300u64;
    let mut total = 0.0f64;
    for i in 0..n {
        let d = rng.dur(1e5);
        rec.span(Track::Device, Phase::FlashService, i as f64, d);
        total += d;
    }
    // The ring dropped most spans, but the aggregate counted every one.
    assert_eq!(rec.spans_len(), 32);
    assert_eq!(rec.spans_dropped(), n - 32);
    let agg = rec.aggregate();
    assert_eq!(agg.phase_count(Phase::FlashService), n);
    assert_eq!(agg.phase_total_ns(Phase::FlashService).to_bits(), total.to_bits());
    // The retained suffix is the newest 32 spans in order.
    let starts: Vec<f64> = rec.spans().map(|s| s.t_ns).collect();
    let want: Vec<f64> = (n - 32..n).map(|i| i as f64).collect();
    assert_eq!(starts, want);
}

// -----------------------------------------------------------------------
// 3. Tail sampling
// -----------------------------------------------------------------------

#[test]
fn tail_sampler_matches_brute_force_top_k() {
    let k = 8usize;
    let mut tail = TailSampler::new(k);
    let mut rng = Lcg::new(0x7a11_5eed);
    let mut all: Vec<TokenChain> = Vec::new();
    for i in 0..256u32 {
        let c = TokenChain {
            sid: i % 5,
            start_ns: i as f64 * 1e3,
            queue_ns: rng.dur(1e4),
            stall_ns: rng.dur(1e5),
            compute_ns: rng.dur(5e4),
            latency_ns: rng.dur(1e6),
        };
        tail.offer(c);
        all.push(c);
    }
    assert_eq!(tail.len(), k);
    // Brute force: sort all offered chains slowest-first with the sampler's
    // own tiebreak (earlier start, then lower sid) and take the top K.
    all.sort_by(|a, b| {
        b.latency_ns
            .total_cmp(&a.latency_ns)
            .then(a.start_ns.total_cmp(&b.start_ns))
            .then(a.sid.cmp(&b.sid))
    });
    assert_eq!(tail.sorted(), all[..k].to_vec());
}

#[test]
fn identical_token_streams_produce_identical_attribution() {
    let run = || {
        let mut rec = FlightRecorder::new(TraceConfig { tail_k: 4, ..TraceConfig::default() });
        let mut rng = Lcg::new(42);
        let mut start = 0.0f64;
        for i in 0..128u32 {
            let (q, s, c) = (rng.dur(1e4), rng.dur(2e5), rng.dur(9e4));
            let latency = (q + s) + c;
            rec.token(i % 3, start, q, s, c, latency);
            start += latency;
        }
        rec.attribution(24.0)
    };
    assert_eq!(run(), run(), "same stream must yield an identical summary");
}

// -----------------------------------------------------------------------
// 4. Flash integration: device busy time closes bitwise
// -----------------------------------------------------------------------

#[test]
fn flash_service_spans_close_against_device_busy_time() {
    let dev = ripple::config::devices()[0].clone();
    let trace = TraceHandle::new(TraceConfig::default());
    let mut sim = UfsSim::new(dev, 1 << 20);
    sim.set_trace(Some(trace.clone()));

    let mut rng = Lcg::new(0xf1a5_0001);
    let mut waited = 0usize;
    let mut dropped = 0usize;
    let batches = 50usize;
    for i in 0..batches {
        let cmds: Vec<ReadCmd> = (0..1 + (rng.next_u64() % 4) as usize)
            .map(|j| ReadCmd {
                offset: ((i * 7 + j) as u64 * 4096) % (1 << 19),
                len: 4096,
            })
            .collect();
        let t = sim.submit_batch(&cmds);
        sim.advance_compute(rng.dur(5e4));
        // Mix synchronous waits with abandoned speculation: busy time is
        // charged at submit either way, so the identity must still hold.
        if i % 5 == 4 {
            sim.drop_ticket(t);
            dropped += 1;
        } else {
            sim.wait(t);
            waited += 1;
        }
    }

    let stats = sim.stats();
    trace.with(|rec| {
        let agg = rec.aggregate();
        assert_eq!(agg.phase_count(Phase::FlashService), batches as u64);
        assert_eq!(
            agg.phase_total_ns(Phase::FlashService).to_bits(),
            stats.total_busy_ns.to_bits(),
            "device-track span durations must sum to FlashStats::total_busy_ns bitwise"
        );
        // Span-level cross-check on the retained ring (no overflow here).
        assert_eq!(rec.spans_dropped(), 0);
        let ring_sum_bits = rec
            .spans()
            .filter(|s| s.phase == Phase::FlashService)
            .map(|s| s.dur_ns)
            .sum::<f64>()
            .to_bits();
        assert_eq!(ring_sum_bits, stats.total_busy_ns.to_bits());
        let count = |k: MarkKind| rec.marks().filter(|m| m.kind == k).count();
        assert_eq!(count(MarkKind::FlashSubmit), batches);
        assert_eq!(count(MarkKind::FlashComplete), waited);
        assert_eq!(count(MarkKind::FlashDrop), dropped);
        // Service spans live on the device track only.
        assert!(rec
            .spans()
            .filter(|s| s.phase == Phase::FlashService)
            .all(|s| s.track == Track::Device));
    });
}

// -----------------------------------------------------------------------
// 5. Serve integration: phase sums close against RunMetrics, export is
//    deterministic
// -----------------------------------------------------------------------

fn traced_tiny_serve() -> (ServeOutcome, TraceHandle) {
    let mut w = tiny_workload();
    w.eval_tokens = 12;
    let spec = SystemSpec::of(System::Ripple, w.model.ffn_linears);
    let cfg = ServeConfig { sessions: 3, ..Default::default() };
    let trace = TraceHandle::new(TraceConfig::default());
    let out = run_serve_traced(&w, System::Ripple, spec, &cfg, Some(&trace)).unwrap();
    (out, trace)
}

#[test]
fn serve_phase_sums_close_against_run_metrics_bitwise() {
    let (out, trace) = traced_tiny_serve();
    trace.with(|rec| {
        let agg = rec.aggregate();
        assert_eq!(agg.tokens(), out.metrics.tokens);
        // Both sides accumulate the same per-token f64s in the same order
        // from 0.0, so the sums agree bit-for-bit, not just within epsilon.
        assert_eq!(
            agg.phase_total_ns(Phase::FlashQueue).to_bits(),
            out.metrics.totals.stall_ns.to_bits(),
            "Σ FlashQueue spans must equal RunMetrics.totals.stall_ns bitwise"
        );
        assert_eq!(
            agg.phase_total_ns(Phase::Compute).to_bits(),
            out.metrics.compute_ns.to_bits(),
            "Σ Compute spans must equal RunMetrics.compute_ns bitwise"
        );
        // Serve latencies are measured off the shared clock rather than
        // re-summed per phase, so closure is near-exact, not bitwise.
        let err = (agg.latency_ns() - agg.accounted_ns()).abs();
        let scale = agg.latency_ns().abs().max(1.0);
        assert!(
            err / scale < 1e-9,
            "serve closure error too large: {err} ns over {scale} ns total"
        );
        // Every session decoded under the recorder: one track per session.
        for sid in 0..3u32 {
            assert!(
                rec.spans().any(|s| s.track == Track::Session(sid)),
                "session {sid} recorded no spans"
            );
        }
    });
}

#[test]
fn serve_trace_export_is_bit_identical_and_valid() {
    let (_, ta) = traced_tiny_serve();
    let (_, tb) = traced_tiny_serve();
    let a = ta.with(|rec| chrome_trace_json(rec));
    let b = tb.with(|rec| chrome_trace_json(rec));
    assert_eq!(a, b, "identical traced runs must export identical bytes");

    let check = validate_chrome_trace(&a).expect("exported trace must validate");
    assert!(check.events > 0);
    // At least the three session tracks; the device track joins once any
    // demand read hits flash.
    assert!(check.tracks >= 3, "expected >= 3 tracks, got {}", check.tracks);

    // The attribution summary is equally deterministic across runs.
    let at_a = ta.with(|rec| rec.attribution(24.0));
    let at_b = tb.with(|rec| rec.attribution(24.0));
    assert_eq!(at_a, at_b);
}
