//! Integration: offline placement + online pipeline against the flash
//! simulator, cross-validated with brute-force expectations.

use ripple::access::{collapse_runs, plan_runs};
use ripple::bench::workloads::{run_experiment, tiny_workload, System};
use ripple::cache::{KeySpace, NeuronCache};
use ripple::coact::CoactStats;
use ripple::config::devices;
use ripple::flash::UfsSim;
use ripple::neuron::{Layout, NeuronSpace};
use ripple::pipeline::{IoPipeline, PipelineConfig};
use ripple::placement::{search, GreedyParams};
use ripple::trace::{DatasetProfile, TraceGen};

fn mk_pipeline(
    layouts: Vec<Layout>,
    space: NeuronSpace,
    collapse: bool,
    cache_cap: usize,
) -> (IoPipeline, NeuronCache, UfsSim) {
    let cache = NeuronCache::from_config("s3fifo", cache_cap, KeySpace::of(&space), 3).unwrap();
    let cfg = PipelineConfig {
        bundle_bytes: space.bundle_bytes,
        collapse,
        initial_threshold: 2,
        max_threshold: 8,
        window: 8,
        sub_reads_per_run: 1,
    };
    let sim = UfsSim::new(devices()[0].clone(), space.image_bytes());
    (IoPipeline::new(cfg, space, layouts), cache, sim)
}

/// With no cache and no collapse, per-token command count must equal the
/// brute-force run count of the activated slots under the layout.
#[test]
fn pipeline_commands_match_bruteforce_runs() {
    let n = 256;
    let mut tg = TraceGen::new(2, n, 40, &DatasetProfile::alpaca(), 5, 6);
    let calib = tg.generate(100);
    let layouts: Vec<Layout> = (0..2)
        .map(|l| search(&CoactStats::from_trace_layer(&calib, l), GreedyParams::default()).layout)
        .collect();
    let space = NeuronSpace::new(2, n, 128);
    let (mut pipeline, mut cache, mut sim) = mk_pipeline(layouts.clone(), space, false, 0);

    let eval = tg.generate(30);
    for tok in &eval.tokens {
        let before = sim.stats().total_commands;
        let t = pipeline.step_token(&mut cache, &mut sim, tok);
        let after = sim.stats().total_commands;
        let expect: usize = tok
            .iter()
            .enumerate()
            .map(|(l, act)| plan_runs(&layouts[l].slots_for(act)).len())
            .sum();
        assert_eq!((after - before) as usize, expect);
        assert_eq!(t.commands as usize, expect);
    }
}

/// Collapse must never issue more commands than no-collapse, and total
/// simulated time must be no worse.
#[test]
fn collapse_is_never_worse() {
    let n = 512;
    let mut tg = TraceGen::new(1, n, 64, &DatasetProfile::wikitext(), 9, 2);
    let calib = tg.generate(120);
    let layout = search(&CoactStats::from_trace_layer(&calib, 0), GreedyParams::default()).layout;
    let space = NeuronSpace::new(1, n, 2048);

    let eval = tg.generate(50);
    let (mut p_off, mut cache_off, mut sim_off) =
        mk_pipeline(vec![layout.clone()], space.clone(), false, 0);
    let (mut p_on, mut cache_on, mut sim_on) = mk_pipeline(vec![layout], space, true, 0);
    for tok in &eval.tokens {
        p_off.step_token(&mut cache_off, &mut sim_off, tok);
        p_on.step_token(&mut cache_on, &mut sim_on, tok);
    }
    assert!(sim_on.stats().total_commands <= sim_off.stats().total_commands);
    assert!(sim_on.clock_ns() <= sim_off.clock_ns() * 1.02);
}

/// End-to-end ordering of the paper's systems on a correlated workload.
#[test]
fn system_ordering_holds() {
    let w = tiny_workload();
    let flash = run_experiment(&w, System::LlmFlash).unwrap();
    let off = run_experiment(&w, System::RippleOffline).unwrap();
    let full = run_experiment(&w, System::Ripple).unwrap();
    // offline placement helps; online stage helps further (or at least
    // does not hurt beyond noise)
    assert!(off.latency_ms() < flash.latency_ms());
    assert!(full.latency_ms() <= off.latency_ms() * 1.05);
}

/// The cache reduces traffic on repeated activation patterns, and the
/// linking admission never breaks correctness of the filter/admit cycle.
#[test]
fn cache_integration_reduces_traffic() {
    let n = 128;
    let space = NeuronSpace::new(1, n, 256);
    let (mut pipeline, mut cache, mut sim) =
        mk_pipeline(vec![Layout::identity(n)], space, false, 64);
    let tok = vec![vec![1u32, 2, 3, 50, 51, 90]];
    let t1 = pipeline.step_token(&mut cache, &mut sim, &tok);
    let t2 = pipeline.step_token(&mut cache, &mut sim, &tok);
    assert!(t2.read_bundles < t1.read_bundles);
    assert_eq!(t2.cached_bundles + t2.read_bundles - t2.extra_bundles, 6);
}

/// Collapse plans cover exactly the demanded slots plus accounted extras
/// under randomized stress (brute-force cross-check of plan_volume).
#[test]
fn randomized_collapse_accounting() {
    use ripple::util::rng::Rng;
    let mut rng = Rng::new(0xFEED);
    for _ in 0..500 {
        let n = 512;
        let k = rng.range(1, 80);
        let mut slots: Vec<u32> = rng
            .sample_indices(n, k)
            .into_iter()
            .map(|x| x as u32)
            .collect();
        slots.sort_unstable();
        let threshold = rng.below(6) as u32;
        let runs = collapse_runs(&plan_runs(&slots), threshold);
        // brute-force: expected covered set
        let mut covered = std::collections::HashSet::new();
        for r in &runs {
            for s in r.start..r.end() {
                covered.insert(s);
            }
        }
        for &s in &slots {
            assert!(covered.contains(&s));
        }
        let (total, extra) = ripple::access::plan_volume(&runs);
        assert_eq!(total as usize, covered.len());
        assert_eq!((total - extra) as usize, slots.len());
    }
}
