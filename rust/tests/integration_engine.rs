//! Integration: the real PJRT engine + coordinator against the JAX
//! golden vectors. All tests skip gracefully when artifacts/ is absent
//! (fresh checkout before `make artifacts`).

use ripple::coordinator::{BatcherConfig, Server, ServerOptions, TcpClient, TcpFrontend};
use ripple::engine::{Engine, EngineOptions, Golden, Selection};
use ripple::runtime::{artifacts_available, default_artifacts_dir};

fn skip() -> bool {
    if artifacts_available(default_artifacts_dir()) {
        false
    } else {
        eprintln!("skipping: run `make artifacts` first");
        true
    }
}

/// The full three-layer stack reproduces the JAX reference decode:
/// PJRT attention + Pallas sparse FFN over flash-fetched bundles ==
/// pure-jnp dense golden, token for token.
#[test]
fn three_layer_stack_matches_jax_golden() {
    if skip() {
        return;
    }
    let golden = Golden::load(default_artifacts_dir()).unwrap();
    let mut e = Engine::load(default_artifacts_dir(), EngineOptions::default()).unwrap();
    let out = e
        .generate(&[golden.prompt.clone()], golden.generated.len(), false)
        .unwrap();
    assert_eq!(out[0], golden.generated);

    // and the dense PJRT path reproduces the final logits numerically
    e.reset_sequence().unwrap();
    let mut logits = Vec::new();
    for &b in &golden.prompt {
        logits = e.decode_step_dense(&[b]).unwrap();
    }
    for &b in &golden.generated {
        logits = e.decode_step_dense(&[b]).unwrap();
    }
    let max_err = logits
        .iter()
        .zip(&golden.last_logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 1e-2, "max logits err {max_err}");
}

/// Server integration: batched serving produces the same bytes as a
/// direct engine run with the same batch composition.
#[test]
fn server_matches_direct_engine() {
    if skip() {
        return;
    }
    let prompts: Vec<Vec<u8>> = vec![
        b"the quick ".to_vec(),
        b"pack my ".to_vec(),
        b"01234 ".to_vec(),
        b"llm ".to_vec(),
    ];
    let max_new = 6;

    let mut engine =
        Engine::load(default_artifacts_dir(), EngineOptions { batch: 4, ..Default::default() })
            .unwrap();
    let direct = engine.generate(&prompts, max_new, false).unwrap();

    // force the batcher to group all four (large window)
    let opts = ServerOptions {
        n_workers: 1,
        batcher: BatcherConfig {
            max_batch: 4,
            max_wait: std::time::Duration::from_millis(300),
        },
        ..Default::default()
    };
    let server = Server::start(default_artifacts_dir(), opts).unwrap();
    let rxs: Vec<_> = prompts
        .iter()
        .map(|p| server.submit(p.clone(), max_new))
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.recv_timeout(std::time::Duration::from_secs(120)).unwrap();
        assert_eq!(r.generated, direct[i], "request {i} diverged");
        assert_eq!(r.batch_size, 4, "batcher failed to group request {i}");
    }
    server.shutdown();
}

/// Predictor-mode serving stays close to oracle-mode output quality:
/// the low-rank predictor with slack threshold catches enough neurons
/// that most generated tokens agree.
#[test]
fn predictor_close_to_oracle() {
    if skip() {
        return;
    }
    let prompt = b"the quick brown fox ".to_vec();
    let n = 12;
    let mut oracle =
        Engine::load(default_artifacts_dir(), EngineOptions::default()).unwrap();
    let a = oracle.generate(&[prompt.clone()], n, false).unwrap();
    let mut pred = Engine::load(
        default_artifacts_dir(),
        EngineOptions {
            selection: Selection::Predictor { threshold: -0.2 },
            ..Default::default()
        },
    )
    .unwrap();
    let b = pred.generate(&[prompt], n, false).unwrap();
    let agree = a[0].iter().zip(&b[0]).filter(|(x, y)| x == y).count();
    assert!(
        agree * 2 >= n,
        "predictor diverged: oracle={:?} pred={:?}",
        String::from_utf8_lossy(&a[0]),
        String::from_utf8_lossy(&b[0])
    );
}

/// Trace recording + placement + re-serve: full offline/online loop on
/// real activations (the serve_llm example, in miniature).
#[test]
fn offline_online_loop_on_real_traces() {
    if skip() {
        return;
    }
    // isolate the placement effect: collapse off, plain S3-FIFO, so the
    // baseline isn't already one-command-per-layer via gap merging (the
    // opt-micro layer is small enough for collapse to flatten everything)
    let opts = EngineOptions {
        collapse: false,
        cache_policy: "s3fifo".into(),
        ..Default::default()
    };
    let mut e = Engine::load(default_artifacts_dir(), opts).unwrap();
    let base_out = e.generate(&[b"hello world ".to_vec()], 5, false).unwrap();
    let base_cmds = e.io_metrics.totals.commands as f64 / e.io_metrics.tokens as f64;

    let trace = e.calibrate(b"the quick brown fox jumps ", 32).unwrap();
    assert!(trace.n_tokens() >= 32);
    let layouts =
        ripple::placement::place_model(&trace, ripple::placement::GreedyParams::default(), 2);
    e.set_layouts(layouts).unwrap();

    let out = e.generate(&[b"hello world ".to_vec()], 5, false).unwrap();
    assert_eq!(out, base_out, "placement changed outputs");
    let cmds = e.io_metrics.totals.commands as f64 / e.io_metrics.tokens as f64;
    assert!(
        cmds < base_cmds,
        "placement should reduce commands/token: {cmds:.1} vs {base_cmds:.1}"
    );
}

/// TCP front-end round trip: PING, error paths, and a real generation
/// compared against a direct engine run.
#[test]
fn tcp_frontend_serves_generation() {
    if skip() {
        return;
    }
    let server = std::sync::Arc::new(
        Server::start(default_artifacts_dir(), ServerOptions::default()).unwrap(),
    );
    let fe = TcpFrontend::start(server.clone(), 0).unwrap();
    let mut client = TcpClient::connect(fe.addr()).unwrap();

    assert_eq!(client.roundtrip("PING").unwrap(), "PONG");
    assert!(client.roundtrip("BOGUS").unwrap().starts_with("ERR"));
    assert!(client.roundtrip("GEN abc hi").unwrap().starts_with("ERR"));

    let generated = client.generate("the quick ", 4).unwrap();
    assert_eq!(generated.len(), 4);

    // a second client on a fresh connection works concurrently
    let mut client2 = TcpClient::connect(fe.addr()).unwrap();
    let g2 = client2.generate("the quick ", 4).unwrap();
    assert_eq!(g2, generated, "same prompt should generate same bytes");

    assert!(client.roundtrip("QUIT").is_ok());
    fe.stop();
}
