//! Property battery for the event-driven fleet simulator
//! (DESIGN.md §Fleet): a hand-rolled LCG (no external proptest crate,
//! same style as `arbiter_props.rs`) drives randomized event multisets
//! and fleet configurations against the simulator's structural
//! contracts.
//!
//! Invariants:
//! * heap order: for any multiset of events, pop order is the unique
//!   `(t_ns, kind, sid)` total order — independent of insertion order,
//!   with nothing lost or duplicated across tie-breaks;
//! * event-time monotonicity: the retired-event log of a full fleet run
//!   never steps backwards in virtual time;
//! * conservation: every offered token is decoded or rejected, every
//!   offered session resolves exactly one way, and the event counts
//!   close (arrival events == offered sessions, token events ==
//!   completed tokens, log length == the sum of the kind counters);
//! * determinism: rerunning a configuration reproduces the summary and
//!   the retired-event log bit-for-bit.

use ripple::bench::workloads::{tiny_workload, System, SystemSpec};
use ripple::coordinator::fleet::{EVENT_ARRIVAL, EVENT_TICKET, EVENT_TOKEN};
use ripple::coordinator::{run_fleet, EventHeap, FleetConfig, FleetEvent, FleetScheduler};
use ripple::trace::ArrivalProcess;

/// Deterministic 64-bit LCG (Knuth MMIX constants).
struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self
            .0
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.0
    }

    /// Uniform-ish value in `[0, bound)` (bound > 0).
    fn below(&mut self, bound: u64) -> u64 {
        (self.next() >> 11) % bound
    }
}

/// The strict `(t_ns, kind, sid)` key the heap is specified to pop in.
/// For the non-negative finite times used here, `f64::to_bits` order
/// equals `total_cmp` order, so the key is a plain integer tuple.
fn key(e: &FleetEvent) -> (u64, u8, u32) {
    (e.t_ns.to_bits(), e.kind, e.sid)
}

fn drain(heap: &mut EventHeap) -> Vec<FleetEvent> {
    let mut out = Vec::with_capacity(heap.len());
    while let Some(e) = heap.pop() {
        out.push(e);
    }
    out
}

#[test]
fn heap_pop_order_is_total_and_insertion_order_independent() {
    let mut rng = Lcg(0x5EED_F1E1);
    for trial in 0..60 {
        let n = 1 + rng.below(64) as usize;
        let mut events: Vec<FleetEvent> = (0..n)
            .map(|_| FleetEvent {
                // few distinct times, kinds and ids -> plenty of exact
                // ties to exercise the (kind, sid) tie-break
                t_ns: rng.below(8) as f64 * 100.0,
                kind: [EVENT_ARRIVAL, EVENT_TICKET, EVENT_TOKEN][rng.below(3) as usize],
                sid: rng.below(6) as u32,
            })
            .collect();
        let mut heap = EventHeap::with_capacity(n);
        for &e in &events {
            heap.push(e);
        }
        let popped = drain(&mut heap);
        // no lost or duplicated events across tie-breaks ...
        assert_eq!(popped.len(), n, "trial {trial}: lost or duplicated events");
        // ... and pop order is exactly the sorted (t, kind, sid) order
        let mut want = events.clone();
        want.sort_by_key(key);
        assert!(
            want.iter().zip(&popped).all(|(a, b)| key(a) == key(b)),
            "trial {trial}: pop order violates the (t, kind, sid) total order"
        );
        // Fisher-Yates shuffle, reinsert, repop: identical sequence
        for i in (1..events.len()).rev() {
            let j = rng.below(i as u64 + 1) as usize;
            events.swap(i, j);
        }
        let mut heap = EventHeap::with_capacity(n);
        for &e in &events {
            heap.push(e);
        }
        let reshuffled = drain(&mut heap);
        assert!(
            popped.iter().zip(&reshuffled).all(|(a, b)| key(a) == key(b)),
            "trial {trial}: pop order depends on insertion order"
        );
    }
}

/// A random-but-reproducible fleet configuration spanning every axis:
/// all four arrival processes, both schedulers, bounded/unbounded
/// admission, and no/loose/impossible SLOs.
fn random_config(rng: &mut Lcg) -> FleetConfig {
    let arrival = match rng.below(4) {
        0 => ArrivalProcess::Fixed { spacing_ns: rng.below(3) as f64 * 250_000.0 },
        1 => ArrivalProcess::Poisson { rate_per_s: 500.0 + rng.below(8_000) as f64 },
        2 => ArrivalProcess::Bursty {
            rate_per_s: 500.0 + rng.below(8_000) as f64,
            burst: 1 + rng.below(4) as usize,
        },
        _ => ArrivalProcess::Diurnal {
            rate_per_s: 500.0 + rng.below(8_000) as f64,
            period_s: 0.002 + rng.below(50) as f64 * 1e-4,
            depth: rng.below(100) as f64 / 100.0,
        },
    };
    let scheduler = if rng.below(2) == 0 {
        FleetScheduler::Fifo
    } else {
        FleetScheduler::ShortestRemaining
    };
    let admission_bound = match rng.below(3) {
        0 => None,
        _ => Some(rng.below(4) as usize),
    };
    let slo_ns = match rng.below(3) {
        0 => f64::INFINITY,
        1 => 50_000.0 + rng.below(2_000_000) as f64,
        _ => 1.0, // tighter than any real token: everything violates
    };
    FleetConfig {
        sessions: 2 + rng.below(9) as usize,
        max_concurrent: 1 + rng.below(4) as usize,
        arrival,
        arrival_seed: rng.next(),
        scheduler,
        admission_bound,
        slo_ns,
        ..FleetConfig::default()
    }
}

#[test]
fn random_fleets_conserve_load_and_retire_monotone_events() {
    let mut w = tiny_workload();
    w.eval_tokens = 6;
    let spec = SystemSpec::of(System::Ripple, w.model.ffn_linears);
    let mut rng = Lcg(0x5EED_F1E2);
    for trial in 0..8 {
        let cfg = random_config(&mut rng);
        let out = run_fleet(&w, System::Ripple, spec, &cfg).unwrap();
        let fs = &out.fleet;
        assert!(fs.conserves_load(), "trial {trial} ({cfg:?}): {fs:?}");
        // the retired-event log never steps backwards in virtual time
        let log = &out.stats.events;
        assert!(
            log.windows(2).all(|p| p[0].t_ns <= p[1].t_ns),
            "trial {trial} ({cfg:?}): event log steps backwards in time"
        );
        // event counts close: nothing lost, nothing duplicated
        assert_eq!(fs.arrival_events, fs.offered_sessions as u64, "trial {trial}");
        assert_eq!(fs.token_events, fs.completed_tokens, "trial {trial}");
        assert_eq!(
            log.len() as u64,
            fs.arrival_events + fs.token_events + fs.ticket_events,
            "trial {trial}"
        );
        let count = |k: u8| log.iter().filter(|e| e.kind == k).count() as u64;
        assert_eq!(count(EVENT_ARRIVAL), fs.arrival_events, "trial {trial}");
        assert_eq!(count(EVENT_TOKEN), fs.token_events, "trial {trial}");
        assert_eq!(count(EVENT_TICKET), fs.ticket_events, "trial {trial}");
        // admitted streams are finite, so every admitted session ends
        assert_eq!(fs.completed_sessions, fs.admitted_sessions, "trial {trial}");
        // the fleet's token count is the aggregate recorder's
        assert_eq!(fs.completed_tokens, out.metrics.tokens, "trial {trial}");
        assert!(fs.slo_violations <= fs.completed_tokens, "trial {trial}");
    }
}

#[test]
fn reruns_reproduce_summaries_and_event_logs_bit_for_bit() {
    let mut w = tiny_workload();
    w.eval_tokens = 6;
    let spec = SystemSpec::of(System::Ripple, w.model.ffn_linears);
    let mut rng = Lcg(0x5EED_F1E3);
    for trial in 0..4 {
        let cfg = random_config(&mut rng);
        let a = run_fleet(&w, System::Ripple, spec, &cfg).unwrap();
        let b = run_fleet(&w, System::Ripple, spec, &cfg).unwrap();
        assert_eq!(a.fleet, b.fleet, "trial {trial} ({cfg:?})");
        assert_eq!(
            a.summary.makespan_ms.to_bits(),
            b.summary.makespan_ms.to_bits(),
            "trial {trial}"
        );
        assert_eq!(
            a.summary.p999_ms.to_bits(),
            b.summary.p999_ms.to_bits(),
            "trial {trial}"
        );
        assert_eq!(a.stats.events.len(), b.stats.events.len(), "trial {trial}");
        assert!(
            a.stats
                .events
                .iter()
                .zip(&b.stats.events)
                .all(|(x, y)| key(x) == key(y)),
            "trial {trial}: retired-event logs diverge"
        );
    }
}

#[test]
fn zero_admission_bound_rejects_every_session() {
    // bound 0 means no session may ever wait; since slots are granted
    // only from the waiting queue, the entire offered load is refused —
    // and conservation still closes with zero completed tokens.
    let mut w = tiny_workload();
    w.eval_tokens = 4;
    let spec = SystemSpec::of(System::Ripple, w.model.ffn_linears);
    let cfg = FleetConfig {
        sessions: 5,
        admission_bound: Some(0),
        arrival: ArrivalProcess::Poisson { rate_per_s: 2_000.0 },
        arrival_seed: 11,
        ..FleetConfig::default()
    };
    let out = run_fleet(&w, System::Ripple, spec, &cfg).unwrap();
    let fs = &out.fleet;
    assert_eq!(fs.rejected_sessions, 5);
    assert_eq!(fs.admitted_sessions, 0);
    assert_eq!(fs.completed_tokens, 0);
    assert_eq!(fs.rejected_tokens, fs.offered_tokens);
    assert!(fs.conserves_load());
    assert_eq!(fs.arrival_events, 5);
    assert_eq!(fs.token_events, 0);
    assert!((fs.rejection_rate - 1.0).abs() < 1e-12);
}
