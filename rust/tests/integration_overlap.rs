//! Acceptance tests for the overlapped I/O–compute pipeline on the
//! Figure-10 overall workload (OPT-350M, OnePlus 12, alpaca):
//!
//! * with prefetch enabled, overlap ratio > 0 and simulated end-to-end
//!   token latency strictly below the synchronous baseline;
//! * with prefetch disabled, the flash timeline is bit-identical to the
//!   historical synchronous pipeline (determinism regression).

use ripple::bench::workloads::{bench_workload, run_experiment, System, Workload};
use ripple::cache::{KeySpace, NeuronCache};
use ripple::flash::UfsSim;
use ripple::neuron::NeuronSpace;
use ripple::pipeline::{IoPipeline, PipelineConfig};
use ripple::trace::DatasetProfile;

/// The fig10 overall workload, trimmed for test time (2 representative
/// layers, shorter calibration — every reported metric is a ratio or
/// per-layer figure, so the trim preserves the comparison; see
/// bench/workloads.rs module docs on layer scaling).
fn fig10_workload() -> Workload {
    let mut w = bench_workload("OPT-350M", 0, DatasetProfile::alpaca());
    w.calib_tokens = 96;
    w.eval_tokens = 24;
    w.knn = 16;
    w
}

#[test]
fn overlap_beats_sync_baseline_on_fig10_workload() {
    let w = fig10_workload();
    let sync = run_experiment(&w, System::Ripple).unwrap();
    // the synchronous schedule hides nothing
    assert!(sync.overlap_ratio().abs() < 1e-9);
    assert_eq!(sync.metrics.totals.prefetch_hit_bundles, 0);

    let mut wp = fig10_workload();
    wp.prefetch.enabled = true;
    let pre = run_experiment(&wp, System::Ripple).unwrap();

    assert!(
        pre.overlap_ratio() > 0.0,
        "overlap ratio must be positive, got {}",
        pre.overlap_ratio()
    );
    assert!(
        pre.metrics.totals.prefetch_hit_bundles > 0,
        "speculation never hit"
    );
    assert!(
        pre.e2e_ms() < sync.e2e_ms(),
        "overlapped e2e {:.3}ms must beat synchronous {:.3}ms",
        pre.e2e_ms(),
        sync.e2e_ms()
    );
    // host stall is what shrank; device busy may grow (speculative bytes)
    assert!(pre.metrics.totals.stall_ns < sync.metrics.totals.stall_ns);
}

#[test]
fn prefetch_disabled_reproduces_sync_timeline_bit_identically() {
    // Same trace stream through (a) the historical synchronous step and
    // (b) the overlapped step with prefetch disabled and a zero compute
    // window: the flash timelines must match bit for bit.
    let w = fig10_workload();
    let calib = w.calibration_trace();
    let eval = w.eval_trace(&w.dataset);
    let layouts =
        ripple::bench::workloads::layouts_for(System::Ripple, &calib, w.knn, w.threads).0;

    let mk = |layouts: Vec<ripple::neuron::Layout>| {
        let bundle_bytes = w.model.bundle_bytes(w.precision);
        let space =
            NeuronSpace::new(w.sim_layers, w.model.neurons_per_layer, bundle_bytes);
        let cache = NeuronCache::from_config(
            "linking",
            (space.total() as f64 * w.cache_ratio) as usize,
            KeySpace::of(&space),
            w.seed,
        )
        .unwrap();
        let cfg = PipelineConfig {
            bundle_bytes,
            collapse: true,
            initial_threshold: 4,
            max_threshold: ((w.device.knee_bytes() / bundle_bytes as f64) as u32).max(1),
            window: 16,
            sub_reads_per_run: 1,
        };
        let sim = UfsSim::new(w.device.clone(), space.image_bytes());
        (IoPipeline::new(cfg, space, layouts), cache, sim)
    };

    let (mut p_sync, mut cache_sync, mut sim_sync) = mk(layouts.clone());
    let (mut p_over, mut cache_over, mut sim_over) = mk(layouts);
    for tok in &eval.tokens {
        p_sync.step_token(&mut cache_sync, &mut sim_sync, tok);
        p_over.step_token_overlapped(&mut cache_over, &mut sim_over, tok, 0.0);
    }
    let (a, b) = (sim_sync.stats(), sim_over.stats());
    assert_eq!(sim_sync.clock_ns().to_bits(), sim_over.clock_ns().to_bits());
    assert_eq!(a.total_busy_ns.to_bits(), b.total_busy_ns.to_bits());
    assert_eq!(a.total_stall_ns.to_bits(), b.total_stall_ns.to_bits());
    assert_eq!(a.total_commands, b.total_commands);
    assert_eq!(a.total_bytes, b.total_bytes);
    assert_eq!(a.total_batches, b.total_batches);
    assert_eq!(a.total_hidden_ns.to_bits(), b.total_hidden_ns.to_bits());
}

#[test]
fn prefetch_stats_flow_through_experiment_result() {
    let mut w = fig10_workload();
    w.eval_tokens = 12;
    w.prefetch.enabled = true;
    let r = run_experiment(&w, System::Ripple).unwrap();
    let t = &r.metrics.totals;
    // accounting sanity: hits are demanded, waste is read-but-unused;
    // both moved real bytes through the device timeline
    assert!(t.prefetch_hit_bundles + t.prefetch_wasted_bundles > 0);
    assert!(t.read_bundles >= t.prefetch_hit_bundles + t.prefetch_wasted_bundles);
    assert!(t.stall_ns <= t.elapsed_ns + 1e-6);
    assert!(r.metrics.prefetch_hit_ratio() > 0.0);
    assert!(r.metrics.prefetch_hit_ratio() <= 1.0);
    // e2e decomposition holds
    let want =
        (t.stall_ns + r.metrics.compute_ns) / r.metrics.tokens as f64;
    assert!((r.metrics.mean_e2e_ns() - want).abs() < 1e-6);
}
