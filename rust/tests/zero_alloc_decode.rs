//! The zero-allocation decode gate (§Perf, DESIGN.md).
//!
//! A counting global allocator wraps the system allocator; after one
//! warmup pass over the fig10 single-stream workload (which grows every
//! scratch buffer to its high-water mark), replaying the same token
//! stream through the decode step must perform ZERO heap allocations —
//! the dense slot-indexed caches, the step scratch arena, and the
//! pooled speculation buffers together make the steady-state per-token
//! path allocation- and hash-free. The same gate covers the
//! multi-session serve round and the event-driven fleet step (whose
//! heap, retired-event log and queues are all pre-sized), and it holds
//! with the flight recorder attached: the span/mark rings, histograms
//! and tail sampler are all pre-sized at construction (DESIGN.md
//! §Observability), so tracing is free of steady-state allocations too.
//! The parallel plan/commit rounds (DESIGN.md §Parallel-decode) are
//! gated last: a pooled round on live worker threads must match.
//!
//! This file is its own test binary on purpose: a `#[global_allocator]`
//! is process-wide, and the counter must not race other test threads.

use std::alloc::{GlobalAlloc, Layout, System as SystemAlloc};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use ripple::bench::workloads::{
    bench_workload, cache_capacity, layouts_for, neuron_space, pipeline_config,
    pipeline_with, System, SystemSpec, Workload,
};
use ripple::cache::{KeySpace, NeuronCache};
use ripple::coordinator::{FleetConfig, FleetManager, ServeConfig, SessionManager};
use ripple::flash::UfsSim;
use ripple::pipeline::IoPipeline;
use ripple::prefetch::Prefetcher;
use ripple::trace::{DatasetProfile, Trace};

static COUNTING: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

impl CountingAlloc {
    #[inline]
    fn count() {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
    }
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        Self::count();
        SystemAlloc.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        Self::count();
        SystemAlloc.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        Self::count();
        SystemAlloc.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        // frees are not allocations; steady state may still return
        // nothing to the allocator, but we only gate acquisitions
        SystemAlloc.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Allocations performed by `f` while the counter is armed.
fn count_allocs(f: impl FnOnce()) -> u64 {
    ALLOCS.store(0, Ordering::SeqCst);
    COUNTING.store(true, Ordering::SeqCst);
    f();
    COUNTING.store(false, Ordering::SeqCst);
    ALLOCS.load(Ordering::SeqCst)
}

/// Prove the counter is live before trusting a zero reading.
fn assert_counter_works() {
    let sanity = count_allocs(|| {
        let v: Vec<u64> = Vec::with_capacity(32);
        std::hint::black_box(&v);
    });
    assert!(sanity > 0, "counting allocator saw no allocation from Vec::with_capacity");
}

/// The fig10 single-stream point (OPT-350M / OnePlus 12 / alpaca /
/// RIPPLE), shrunk for test speed exactly like the golden tests do.
fn fig10_workload() -> Workload {
    let mut w = bench_workload("OPT-350M", 0, DatasetProfile::alpaca());
    w.calib_tokens = 96;
    w.eval_tokens = 24;
    w.sim_layers = 2;
    w.knn = 16;
    w.threads = 2;
    w
}

fn build(w: &Workload) -> (IoPipeline, NeuronCache, UfsSim, Trace) {
    build_with_policy(w, None)
}

/// `build`, with the DRAM eviction policy swapped out (the cache-lab
/// gate runs the same workload under every ISSUE 9 policy).
fn build_with_policy(
    w: &Workload,
    policy: Option<&'static str>,
) -> (IoPipeline, NeuronCache, UfsSim, Trace) {
    let mut spec = SystemSpec::of(System::Ripple, w.model.ffn_linears);
    if let Some(p) = policy {
        spec.cache_policy = p;
    }
    let calib = w.calibration_trace();
    let (layouts, _) = layouts_for(System::Ripple, &calib, w.knn, w.threads);
    let (mut pipeline, cache, sim) = pipeline_with(spec, w, layouts, None, None).unwrap();
    if w.prefetch.enabled {
        let pf = Prefetcher::from_trace(&calib, w.prefetch.clone(), w.threads);
        pipeline.set_prefetcher(Some(pf));
    }
    let eval = w.eval_trace(&w.dataset);
    (pipeline, cache, sim, eval)
}

/// Mirror `run_serve`'s construction for a manager the serve gate can
/// drive round-by-round (shared cache, all sessions arriving at t=0).
fn build_serve(w: &Workload, sessions: usize) -> (SessionManager, UfsSim) {
    let spec = SystemSpec::of(System::Ripple, w.model.ffn_linears);
    let calib = w.calibration_trace();
    let (layouts, _) = layouts_for(System::Ripple, &calib, w.knn, w.threads);
    let space = neuron_space(w);
    let bundle_bytes = space.bundle_bytes;
    let pcfg = pipeline_config(spec, w, None);
    let keys = KeySpace::of(&space);
    let cache =
        NeuronCache::from_config(spec.cache_policy, cache_capacity(w), keys, w.seed)
            .unwrap();
    let pf = w
        .prefetch
        .enabled
        .then(|| Prefetcher::from_trace(&calib, w.prefetch.clone(), w.threads));
    let streams = (0..sessions)
        .map(|sid| {
            let mut p = IoPipeline::new(pcfg.clone(), space.clone(), layouts.clone());
            if let Some(pf) = &pf {
                p.set_prefetcher(Some(pf.clone()));
            }
            (p, w.session_eval_trace(&w.dataset, sid))
        })
        .collect();
    let cfg = ServeConfig { sessions, max_concurrent: sessions, ..ServeConfig::default() };
    let sim = UfsSim::new(w.device.clone(), space.image_bytes());
    let mut m = SessionManager::new(
        cfg,
        streams,
        vec![cache],
        w.compute_ns_per_layer * w.sim_layers as f64,
        bundle_bytes,
    );
    if w.prefetch.enabled {
        m.enable_prefetch(w.compute_ns_per_layer, w.prefetch.budget_bytes * sessions);
    }
    (m, sim)
}

/// Mirror `run_fleet`'s construction for a manager the fleet gate can
/// drive step-by-step (degenerate simultaneous arrivals, two decode
/// slots, shared cache).
fn build_fleet(w: &Workload, sessions: usize) -> (FleetManager, UfsSim) {
    let spec = SystemSpec::of(System::Ripple, w.model.ffn_linears);
    let calib = w.calibration_trace();
    let (layouts, _) = layouts_for(System::Ripple, &calib, w.knn, w.threads);
    let space = neuron_space(w);
    let bundle_bytes = space.bundle_bytes;
    let pcfg = pipeline_config(spec, w, None);
    let keys = KeySpace::of(&space);
    let cache =
        NeuronCache::from_config(spec.cache_policy, cache_capacity(w), keys, w.seed)
            .unwrap();
    let pf = w
        .prefetch
        .enabled
        .then(|| Prefetcher::from_trace(&calib, w.prefetch.clone(), w.threads));
    let streams = (0..sessions)
        .map(|sid| {
            let mut p = IoPipeline::new(pcfg.clone(), space.clone(), layouts.clone());
            if let Some(pf) = &pf {
                p.set_prefetcher(Some(pf.clone()));
            }
            (p, w.session_eval_trace(&w.dataset, sid))
        })
        .collect();
    let cfg = FleetConfig { sessions, max_concurrent: 2, ..FleetConfig::default() };
    let sim = UfsSim::new(w.device.clone(), space.image_bytes());
    let mut m = FleetManager::new(
        cfg,
        streams,
        cache,
        w.compute_ns_per_layer * w.sim_layers as f64,
        bundle_bytes,
    );
    if w.prefetch.enabled {
        m.enable_prefetch(w.compute_ns_per_layer, w.prefetch.budget_bytes * sessions);
    }
    (m, sim)
}

/// One test fn on purpose: the global counter must never observe a
/// concurrent sibling test's allocations, and a single-test binary has
/// no worker threads racing the counting window.
#[test]
fn decode_step_is_allocation_free_after_warmup() {
    assert_counter_works();

    // --- synchronous fig10 path -----------------------------------------
    let w = fig10_workload();
    let (mut pipeline, mut cache, mut sim, eval) = build(&w);
    // warmup: one full pass grows any buffer not already at its bound
    for tok in &eval.tokens {
        pipeline.step_token(&mut cache, &mut sim, tok);
    }
    // steady state: replaying the same stream allocates NOTHING
    let steady = count_allocs(|| {
        for tok in &eval.tokens {
            pipeline.step_token(&mut cache, &mut sim, tok);
        }
    });
    assert_eq!(
        steady, 0,
        "synchronous decode hot path allocated {steady} times after warmup"
    );

    // --- overlapped (speculative prefetch) path -------------------------
    let mut w = fig10_workload();
    w.prefetch.enabled = true;
    w.prefetch.budget_bytes = 32 * w.model.bundle_bytes(w.precision);
    let (mut pipeline, mut cache, mut sim, eval) = build(&w);
    let compute_ns = w.compute_ns_per_layer;
    for tok in &eval.tokens {
        pipeline.step_token_overlapped(&mut cache, &mut sim, tok, compute_ns);
    }
    let steady = count_allocs(|| {
        for tok in &eval.tokens {
            pipeline.step_token_overlapped(&mut cache, &mut sim, tok, compute_ns);
        }
    });
    assert_eq!(
        steady, 0,
        "overlapped decode hot path allocated {steady} times after warmup"
    );

    // --- cache-lab policies on the synchronous decode path ---------------
    // The victim buffer pre-reserves its FIFO ring, the set-associative
    // table is one flat construction-time Vec, and the cost-aware policy
    // reuses the LRU slab-and-freelist layout — so the warmup-then-replay
    // discipline must hold with each of them swapped in for the default.
    for policy in ["victim", "setassoc", "costaware"] {
        let w = fig10_workload();
        let (mut pipeline, mut cache, mut sim, eval) = build_with_policy(&w, Some(policy));
        for tok in &eval.tokens {
            pipeline.step_token(&mut cache, &mut sim, tok);
        }
        let steady = count_allocs(|| {
            for tok in &eval.tokens {
                pipeline.step_token(&mut cache, &mut sim, tok);
            }
        });
        assert_eq!(
            steady, 0,
            "`{policy}` decode hot path allocated {steady} times after warmup"
        );
    }

    // --- steady-state multi-session serve round (synchronous) -----------
    // All manager loop state is hoisted and every recorder pre-sized, so
    // a full decode round — admission scan, one token per session on the
    // shared device, linear departure — touches the allocator not at all.
    let w = fig10_workload();
    let (mut manager, mut serve_sim) = build_serve(&w, 3);
    for _ in 0..20 {
        assert!(manager.step_round(&mut serve_sim), "warmup ended early");
    }
    let steady = count_allocs(|| {
        manager.step_round(&mut serve_sim);
    });
    assert_eq!(
        steady, 0,
        "steady-state serve round allocated {steady} times after warmup"
    );
    assert!(!manager.is_done(), "the gated round must be mid-run, not the finale");

    // --- steady-state serve round, overlapped + arbiter ------------------
    let mut w = fig10_workload();
    w.prefetch.enabled = true;
    w.prefetch.budget_bytes = 32 * w.model.bundle_bytes(w.precision);
    let (mut manager, mut serve_sim) = build_serve(&w, 3);
    for _ in 0..20 {
        assert!(manager.step_round(&mut serve_sim), "warmup ended early");
    }
    let steady = count_allocs(|| {
        manager.step_round(&mut serve_sim);
    });
    assert_eq!(
        steady, 0,
        "steady-state arbitrated serve round allocated {steady} times after warmup"
    );
    assert!(!manager.is_done(), "the gated round must be mid-run, not the finale");

    // --- steady-state fleet step (event-driven, synchronous) -------------
    // The event heap, the retired-event log, the waiting/active queues
    // and every recorder are pre-sized at construction, so one scheduler
    // iteration — retire due events, grant slots, serve a round through
    // the heap — touches the allocator not at all.
    let w = fig10_workload();
    let (mut fleet, mut fleet_sim) = build_fleet(&w, 4);
    for _ in 0..20 {
        assert!(fleet.step(&mut fleet_sim), "fleet warmup ended early");
    }
    let steady = count_allocs(|| {
        fleet.step(&mut fleet_sim);
    });
    assert_eq!(
        steady, 0,
        "steady-state fleet step allocated {steady} times after warmup"
    );
    assert!(!fleet.is_done(), "the gated fleet step must be mid-run, not the finale");

    // --- steady-state fleet step, overlapped + arbiter --------------------
    let mut w = fig10_workload();
    w.prefetch.enabled = true;
    w.prefetch.budget_bytes = 32 * w.model.bundle_bytes(w.precision);
    let (mut fleet, mut fleet_sim) = build_fleet(&w, 4);
    for _ in 0..20 {
        assert!(fleet.step(&mut fleet_sim), "fleet warmup ended early");
    }
    let steady = count_allocs(|| {
        fleet.step(&mut fleet_sim);
    });
    assert_eq!(
        steady, 0,
        "steady-state arbitrated fleet step allocated {steady} times after warmup"
    );
    assert!(!fleet.is_done(), "the gated fleet step must be mid-run, not the finale");

    // --- tracing attached: every recorder structure is pre-sized ---------
    use ripple::obs::{TraceConfig, TraceHandle};

    // synchronous single-stream with per-token span recording
    let w = fig10_workload();
    let (mut pipeline, mut cache, mut sim, eval) = build(&w);
    let trace = TraceHandle::new(TraceConfig::default());
    sim.set_trace(Some(trace.clone()));
    pipeline.set_trace(Some(trace.clone()), 0);
    let compute = w.compute_ns_per_layer * w.sim_layers as f64;
    for tok in &eval.tokens {
        let t0 = sim.clock_ns();
        let io = pipeline.step_token(&mut cache, &mut sim, tok);
        trace.with(|r| r.token(0, t0, 0.0, io.stall_ns, compute, io.stall_ns + compute));
    }
    let steady = count_allocs(|| {
        for tok in &eval.tokens {
            let t0 = sim.clock_ns();
            let io = pipeline.step_token(&mut cache, &mut sim, tok);
            trace
                .with(|r| r.token(0, t0, 0.0, io.stall_ns, compute, io.stall_ns + compute));
        }
    });
    assert_eq!(
        steady, 0,
        "traced synchronous decode allocated {steady} times after warmup"
    );
    assert!(trace.with(|r| r.spans_len()) > 0, "traced run recorded no spans");

    // arbitrated serve round with the recorder on every layer
    let mut w = fig10_workload();
    w.prefetch.enabled = true;
    w.prefetch.budget_bytes = 32 * w.model.bundle_bytes(w.precision);
    let (mut manager, mut serve_sim) = build_serve(&w, 3);
    let trace = TraceHandle::new(TraceConfig::default());
    serve_sim.set_trace(Some(trace.clone()));
    manager.set_trace(Some(trace.clone()));
    for _ in 0..20 {
        assert!(manager.step_round(&mut serve_sim), "traced warmup ended early");
    }
    let steady = count_allocs(|| {
        manager.step_round(&mut serve_sim);
    });
    assert_eq!(
        steady, 0,
        "traced arbitrated serve round allocated {steady} times after warmup"
    );
    assert!(!manager.is_done(), "the gated round must be mid-run, not the finale");

    // event-driven fleet step with the recorder on every layer
    let mut w = fig10_workload();
    w.prefetch.enabled = true;
    w.prefetch.budget_bytes = 32 * w.model.bundle_bytes(w.precision);
    let (mut fleet, mut fleet_sim) = build_fleet(&w, 4);
    let trace = TraceHandle::new(TraceConfig::default());
    fleet_sim.set_trace(Some(trace.clone()));
    fleet.set_trace(Some(trace.clone()));
    for _ in 0..20 {
        assert!(fleet.step(&mut fleet_sim), "traced fleet warmup ended early");
    }
    let steady = count_allocs(|| {
        fleet.step(&mut fleet_sim);
    });
    assert_eq!(
        steady, 0,
        "traced fleet step allocated {steady} times after warmup"
    );
    assert!(!fleet.is_done(), "the gated fleet step must be mid-run, not the finale");
    assert!(trace.with(|r| r.spans_len()) > 0, "traced fleet recorded no spans");

    // --- steady-state PARALLEL serve round (DESIGN.md §Parallel-decode) ---
    // The plan phase writes into per-session `TokenPrep` buffers that
    // warm up like every other scratch arena, the pool's workers park on
    // a futex-backed condvar between rounds, and publishing a round is a
    // lock + two atomic stores — so a pooled round must be as
    // allocation-free as the serial one it bit-matches.
    use ripple::coordinator::with_decode_pool;

    let mut w = fig10_workload();
    w.prefetch.enabled = true;
    w.prefetch.budget_bytes = 32 * w.model.bundle_bytes(w.precision);
    let (mut manager, mut serve_sim) = build_serve(&w, 3);
    with_decode_pool(2, |pool| {
        for _ in 0..20 {
            assert!(
                manager.step_round_pooled(&mut serve_sim, pool),
                "pooled warmup ended early"
            );
        }
        let steady = count_allocs(|| {
            manager.step_round_pooled(&mut serve_sim, pool);
        });
        assert_eq!(
            steady, 0,
            "steady-state pooled serve round allocated {steady} times after warmup"
        );
    });
    assert!(!manager.is_done(), "the gated pooled round must be mid-run, not the finale");

    // event-driven fleet on the same two-phase pool
    let mut w = fig10_workload();
    w.prefetch.enabled = true;
    w.prefetch.budget_bytes = 32 * w.model.bundle_bytes(w.precision);
    let (mut fleet, mut fleet_sim) = build_fleet(&w, 4);
    with_decode_pool(2, |pool| {
        for _ in 0..20 {
            assert!(
                fleet.step_pooled(&mut fleet_sim, pool),
                "pooled fleet warmup ended early"
            );
        }
        let steady = count_allocs(|| {
            fleet.step_pooled(&mut fleet_sim, pool);
        });
        assert_eq!(
            steady, 0,
            "steady-state pooled fleet step allocated {steady} times after warmup"
        );
    });
    assert!(!fleet.is_done(), "the gated pooled fleet step must be mid-run, not the finale");
}
