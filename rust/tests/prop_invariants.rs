//! Cross-module property tests: access-collapse plan invariants and
//! timeline determinism of the overlapped (prefetching) pipeline.
//!
//! Uses the in-repo `util::prop` harness + `util::rng` (the offline
//! registry has no proptest).

use ripple::access::{collapse_runs, plan_runs, plan_volume};
use ripple::bench::workloads::{run_experiment, tiny_workload, System};
use ripple::cache::{Admission, KeySpace, NeuronCache, S3Fifo};
use ripple::flash::UfsSim;
use ripple::neuron::{Layout, NeuronSpace, Slot};
use ripple::pipeline::{IoPipeline, PipelineConfig};
use ripple::prefetch::{PrefetchConfig, Prefetcher};
use ripple::util::prop;
use ripple::util::rng::Rng;

fn gen_slots_and_threshold(rng: &mut Rng, size: usize) -> (Vec<Slot>, u32) {
    let n = size.max(4) * 8;
    let k = rng.range(1, size.max(2) * 2);
    let mut s: Vec<Slot> = rng
        .sample_indices(n, k.min(n))
        .into_iter()
        .map(|x| x as Slot)
        .collect();
    s.sort_unstable();
    let threshold = rng.below(10) as u32;
    (s, threshold)
}

/// Every missed slot is covered by exactly ONE collapsed run (coverage
/// plus disjointness, counted explicitly).
#[test]
fn prop_each_missed_slot_covered_exactly_once() {
    prop::run(
        "collapse-exactly-once",
        prop::Config { cases: 80, max_size: 160, ..Default::default() },
        gen_slots_and_threshold,
        |(slots, threshold)| {
            let runs = collapse_runs(&plan_runs(slots), *threshold);
            for &s in slots {
                let covering =
                    runs.iter().filter(|r| s >= r.start && s < r.end()).count();
                if covering != 1 {
                    return Err(format!("slot {s} covered by {covering} runs"));
                }
            }
            // runs sorted, disjoint, non-touching (a shared boundary
            // would mean a merge the planner missed)
            if !runs.windows(2).all(|w| w[0].end() <= w[1].start) {
                return Err("runs overlap or are unsorted".into());
            }
            Ok(())
        },
    );
}

/// Inside any collapsed run, the gap between consecutive demanded slots
/// never exceeds the collapse threshold, and every run starts and ends
/// on a demanded slot (gap fill is strictly interior).
#[test]
fn prop_no_interior_gap_exceeds_threshold() {
    prop::run(
        "collapse-gap-bound",
        prop::Config { cases: 80, max_size: 160, ..Default::default() },
        gen_slots_and_threshold,
        |(slots, threshold)| {
            let runs = collapse_runs(&plan_runs(slots), *threshold);
            for r in &runs {
                let demanded: Vec<Slot> = slots
                    .iter()
                    .copied()
                    .filter(|&s| s >= r.start && s < r.end())
                    .collect();
                if demanded.first() != Some(&r.start) {
                    return Err(format!("run at {} does not start demanded", r.start));
                }
                if demanded.last() != Some(&(r.end() - 1)) {
                    return Err(format!("run at {} does not end demanded", r.start));
                }
                for w in demanded.windows(2) {
                    let gap = w[1] - w[0] - 1;
                    if gap > *threshold {
                        return Err(format!(
                            "interior gap {gap} > threshold {threshold} in run at {}",
                            r.start
                        ));
                    }
                }
                // extra accounting: run length = demanded + interior fill
                if r.demanded() as usize != demanded.len() {
                    return Err(format!(
                        "run at {} claims {} demanded, found {}",
                        r.start,
                        r.demanded(),
                        demanded.len()
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Collapsing never issues more commands than the uncollapsed plan, and
/// the command count is monotone non-increasing in the threshold.
#[test]
fn prop_collapsed_command_count_monotone() {
    prop::run_bool(
        "collapse-count-monotone",
        prop::Config { cases: 60, max_size: 160, ..Default::default() },
        |rng, size| gen_slots_and_threshold(rng, size).0,
        |slots| {
            let base = plan_runs(slots);
            let mut prev = base.len();
            for t in 0..12u32 {
                let merged = collapse_runs(&base, t);
                if merged.len() > prev || merged.len() > base.len() {
                    return false;
                }
                // volume identity: total - extra == demanded
                let (total, extra) = plan_volume(&merged);
                if total - extra != slots.len() as u64 {
                    return false;
                }
                prev = merged.len();
            }
            true
        },
    );
}

// ---------------------------------------------------------------------------
// Determinism of the overlapped timeline
// ---------------------------------------------------------------------------

fn overlapped_pipeline(
    seed: u64,
    n: usize,
) -> (IoPipeline, NeuronCache, UfsSim, ripple::trace::Trace) {
    use ripple::trace::{DatasetProfile, TraceGen};
    let space = NeuronSpace::new(2, n, 256);
    let layouts = vec![Layout::identity(n), Layout::identity(n)];
    let cache = NeuronCache::new(
        Box::new(S3Fifo::new(n / 4)),
        Admission::Linking { segment_min: 4, segment_p: 0.5 },
        seed,
        KeySpace::of(&space),
    );
    let cfg = PipelineConfig {
        bundle_bytes: 256,
        collapse: true,
        initial_threshold: 3,
        max_threshold: 12,
        window: 8,
        sub_reads_per_run: 1,
    };
    let sim = UfsSim::new(ripple::config::devices()[0].clone(), space.image_bytes());
    let mut p = IoPipeline::new(cfg, space, layouts);
    let mut tg = TraceGen::new(2, n, n / 12, &DatasetProfile::openwebtext(), seed, seed ^ 7);
    let calib = tg.generate(128);
    let pcfg = PrefetchConfig {
        enabled: true,
        budget_bytes: 24 * 256,
        lookahead: 1,
        max_partners: 8,
    };
    p.set_prefetcher(Some(Prefetcher::from_trace(&calib, pcfg, 2)));
    let eval = tg.generate(30);
    (p, cache, sim, eval)
}

/// Two overlapped pipeline runs with the same seed must produce
/// byte-identical `FlashStats` timelines — speculation in flight and all.
#[test]
fn prop_overlapped_timeline_is_byte_identical() {
    for seed in [3u64, 11, 42] {
        let (mut pa, mut cache_a, mut sim_a, eval) = overlapped_pipeline(seed, 384);
        let (mut pb, mut cache_b, mut sim_b, _) = overlapped_pipeline(seed, 384);
        for tok in &eval.tokens {
            pa.step_token_overlapped(&mut cache_a, &mut sim_a, tok, 120_000.0);
            pb.step_token_overlapped(&mut cache_b, &mut sim_b, tok, 120_000.0);
        }
        let (a, b) = (sim_a.stats(), sim_b.stats());
        assert_eq!(a.total_commands, b.total_commands, "seed {seed}");
        assert_eq!(a.total_bytes, b.total_bytes, "seed {seed}");
        assert_eq!(a.total_batches, b.total_batches, "seed {seed}");
        assert_eq!(a.total_busy_ns.to_bits(), b.total_busy_ns.to_bits(), "seed {seed}");
        assert_eq!(a.total_stall_ns.to_bits(), b.total_stall_ns.to_bits(), "seed {seed}");
        assert_eq!(
            a.total_hidden_ns.to_bits(),
            b.total_hidden_ns.to_bits(),
            "seed {seed}"
        );
        assert_eq!(sim_a.clock_ns().to_bits(), sim_b.clock_ns().to_bits(), "seed {seed}");
        assert_eq!(
            sim_a.device_free_ns().to_bits(),
            sim_b.device_free_ns().to_bits(),
            "seed {seed}"
        );
    }
}

/// The whole experiment runner stays byte-deterministic with prefetch
/// enabled (predictor construction, speculation, reconciliation).
#[test]
fn prop_experiment_with_prefetch_deterministic() {
    let mut w = tiny_workload();
    w.eval_tokens = 16;
    w.prefetch.enabled = true;
    let a = run_experiment(&w, System::Ripple).unwrap();
    let b = run_experiment(&w, System::Ripple).unwrap();
    assert_eq!(
        a.metrics.totals.elapsed_ns.to_bits(),
        b.metrics.totals.elapsed_ns.to_bits()
    );
    assert_eq!(
        a.metrics.totals.stall_ns.to_bits(),
        b.metrics.totals.stall_ns.to_bits()
    );
    assert_eq!(a.metrics.totals.commands, b.metrics.totals.commands);
    assert_eq!(a.metrics.totals.bytes, b.metrics.totals.bytes);
    assert_eq!(
        a.metrics.totals.prefetch_hit_bundles,
        b.metrics.totals.prefetch_hit_bundles
    );
    assert_eq!(
        a.metrics.totals.prefetch_wasted_bundles,
        b.metrics.totals.prefetch_wasted_bundles
    );
}
