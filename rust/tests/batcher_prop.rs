//! Property battery for `coordinator::Batcher` (util::prop harness):
//! the dispatch policies the serving paths rely on —
//!
//! * FIFO order is preserved across any mix of `pop_ready` /
//!   `pop_upto` dispatches,
//! * a request polled at its deadline is never dispatched later than
//!   `max_wait` past its enqueue,
//! * `pop_ready` never yields an empty batch and never exceeds
//!   `max_batch`,
//! * `next_deadline_in` is monotone non-increasing as time advances.

use std::time::{Duration, Instant};

use ripple::coordinator::{Batcher, BatcherConfig};
use ripple::util::prop;
use ripple::util::rng::Rng;

#[derive(Clone, Debug)]
enum Op {
    Push,
    AdvanceMs(u64),
    Pop,
    PopUpto(usize),
}

#[derive(Clone, Debug)]
struct Scenario {
    max_batch: usize,
    max_wait_ms: u64,
    ops: Vec<Op>,
}

fn gen_scenario(rng: &mut Rng, size: usize) -> Scenario {
    let max_batch = rng.range(1, 9);
    let max_wait_ms = rng.below(50) as u64;
    let n = size.max(2) * 2;
    let ops = (0..n)
        .map(|_| match rng.below(5) {
            0 | 1 => Op::Push,
            2 => Op::AdvanceMs(rng.below(30) as u64),
            3 => Op::Pop,
            _ => Op::PopUpto(rng.below(6)),
        })
        .collect();
    Scenario { max_batch, max_wait_ms, ops }
}

/// Replaying any op mix, the concatenation of every dispatched batch
/// (plus the final drain) is exactly the push sequence — FIFO with no
/// loss, duplication, or reordering — and every `pop_ready` batch is
/// non-empty and within `max_batch`.
#[test]
fn prop_dispatch_preserves_fifo_order() {
    prop::run(
        "batcher-fifo",
        prop::Config { cases: 80, max_size: 40, ..Default::default() },
        gen_scenario,
        |sc| {
            let t0 = Instant::now();
            let mut b: Batcher<u32> = Batcher::new(BatcherConfig {
                max_batch: sc.max_batch,
                max_wait: Duration::from_millis(sc.max_wait_ms),
            });
            let mut now = t0;
            let mut pushed = 0u32;
            let mut dispatched: Vec<u32> = Vec::new();
            for op in &sc.ops {
                match op {
                    Op::Push => {
                        b.push(pushed, now);
                        pushed += 1;
                    }
                    Op::AdvanceMs(ms) => now += Duration::from_millis(*ms),
                    Op::Pop => {
                        if let Some(batch) = b.pop_ready(now) {
                            if batch.is_empty() {
                                return Err("pop_ready yielded an empty batch".into());
                            }
                            if batch.len() > sc.max_batch {
                                return Err(format!(
                                    "batch of {} exceeds max_batch {}",
                                    batch.len(),
                                    sc.max_batch
                                ));
                            }
                            dispatched.extend(batch);
                        }
                    }
                    Op::PopUpto(n) => {
                        let batch = b.pop_upto(*n);
                        if batch.len() > *n {
                            return Err("pop_upto over-delivered".into());
                        }
                        dispatched.extend(batch);
                    }
                }
            }
            dispatched.extend(b.drain_all());
            let want: Vec<u32> = (0..pushed).collect();
            if dispatched != want {
                return Err(format!("order broken: {dispatched:?} != 0..{pushed}"));
            }
            Ok(())
        },
    );
}

/// Poll the batcher at each request's own deadline (enqueue +
/// max_wait): the request must already be dispatched by then — no
/// request waits beyond `max_wait` when the worker honors the deadline
/// hint.
#[test]
fn prop_no_request_outlives_its_deadline_when_polled() {
    prop::run(
        "batcher-deadline",
        prop::Config { cases: 80, max_size: 32, ..Default::default() },
        |rng, size| {
            let max_batch = rng.range(1, 6);
            let max_wait_ms = 1 + rng.below(40) as u64;
            let gaps: Vec<u64> =
                (0..size.max(1)).map(|_| rng.below(25) as u64).collect();
            (max_batch, max_wait_ms, gaps)
        },
        |(max_batch, max_wait_ms, gaps)| {
            let t0 = Instant::now();
            let max_wait = Duration::from_millis(*max_wait_ms);
            let mut b: Batcher<usize> =
                Batcher::new(BatcherConfig { max_batch: *max_batch, max_wait });
            // enqueue everything at its arrival time
            let mut t = t0;
            let mut enqueue_at = Vec::with_capacity(gaps.len());
            for (i, gap) in gaps.iter().enumerate() {
                t += Duration::from_millis(*gap);
                enqueue_at.push(t);
                b.push(i, t);
            }
            // poll at each request's deadline, in deadline order
            let mut out = vec![false; gaps.len()];
            for (i, &enq) in enqueue_at.iter().enumerate() {
                let deadline = enq + max_wait;
                while let Some(batch) = b.pop_ready(deadline) {
                    for x in batch {
                        out[x] = true;
                    }
                }
                if !out[i] {
                    return Err(format!(
                        "request {i} still queued at its deadline (+{max_wait_ms}ms)"
                    ));
                }
            }
            Ok(())
        },
    );
}

/// With a fixed queue, `next_deadline_in` shrinks (never grows) as the
/// polling time advances, and hits zero at/after the deadline.
#[test]
fn prop_next_deadline_monotone_as_time_advances() {
    prop::run(
        "batcher-deadline-monotone",
        prop::Config { cases: 60, max_size: 24, ..Default::default() },
        |rng, size| {
            let max_wait_ms = rng.below(50) as u64;
            let n_push = rng.range(1, size.max(2));
            let probes: Vec<u64> = (0..8).map(|_| rng.below(30) as u64).collect();
            (max_wait_ms, n_push, probes)
        },
        |(max_wait_ms, n_push, probes)| {
            let t0 = Instant::now();
            let mut b: Batcher<usize> = Batcher::new(BatcherConfig {
                max_batch: usize::MAX >> 1,
                max_wait: Duration::from_millis(*max_wait_ms),
            });
            for i in 0..*n_push {
                b.push(i, t0 + Duration::from_millis(i as u64));
            }
            let mut now = t0;
            let mut prev = b.next_deadline_in(now).expect("non-empty queue");
            for gap in probes {
                now += Duration::from_millis(*gap);
                let d = b.next_deadline_in(now).expect("queue untouched");
                if d > prev {
                    return Err(format!("deadline grew: {d:?} > {prev:?}"));
                }
                prev = d;
            }
            // far past the deadline the wait is zero and the front is due
            let late = now + Duration::from_millis(max_wait_ms + 1000);
            if b.next_deadline_in(late) != Some(Duration::ZERO) {
                return Err("deadline did not saturate at zero".into());
            }
            if b.pop_ready(late).is_none() {
                return Err("front not dispatchable after its deadline".into());
            }
            Ok(())
        },
    );
}

/// An empty batcher never reports a deadline and never dispatches.
#[test]
fn empty_batcher_has_no_deadline_and_no_batches() {
    let now = Instant::now();
    let mut b: Batcher<u8> = Batcher::new(BatcherConfig::default());
    assert!(b.next_deadline_in(now).is_none());
    assert!(b.pop_ready(now + Duration::from_secs(60)).is_none());
    assert!(b.pop_upto(4).is_empty());
}
