//! Property tests for the speculative-prefetch budget arbiter
//! (DESIGN.md §Serving): randomized demand vectors (hand-rolled LCG, no
//! external proptest crate) checked against the arbiter's contract, plus
//! the end-to-end attribution invariant through `run_serve`.
//!
//! Invariants:
//! * budget conservation: grants never exceed per-session demand, and
//!   they sum to exactly `min(global_budget, Σ demand)` — the arbiter is
//!   work-conserving under both policies;
//! * fair-share equity: identical sessions receive identical grants up
//!   to one byte of integer remainder;
//! * attribution closure: per-session prefetch hit/waste counts sum to
//!   the aggregate `RunMetrics` totals for the whole serve run.

use ripple::bench::workloads::{tiny_workload, System, SystemSpec};
use ripple::coordinator::{
    run_serve, ArbiterPolicy, PrefetchArbiter, ServeConfig, SessionDemand,
};

/// Deterministic 64-bit LCG (Knuth MMIX constants).
struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self
            .0
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.0
    }

    /// Uniform-ish value in `[0, bound)` (bound > 0).
    fn below(&mut self, bound: u64) -> u64 {
        (self.next() >> 11) % bound
    }
}

fn policies() -> [ArbiterPolicy; 3] {
    [
        ArbiterPolicy::FairShare,
        ArbiterPolicy::DeadlineAware { target_ns: 1e6 },
        ArbiterPolicy::DeadlineAware { target_ns: 5e4 },
    ]
}

#[test]
fn grants_conserve_the_budget_under_both_policies() {
    let mut rng = Lcg(0x5EED_0001);
    for policy in policies() {
        for trial in 0..200 {
            let n = 1 + rng.below(8) as usize;
            let global = rng.below(1 << 20) as usize;
            let demands: Vec<SessionDemand> = (0..n)
                .map(|_| SessionDemand {
                    demand_bytes: rng.below(256 * 1024) as usize,
                    mean_latency_ns: rng.below(4_000_000) as f64,
                })
                .collect();
            let mut arb = PrefetchArbiter::new(policy, global);
            let grants = arb.arbitrate(&demands).to_vec();

            assert_eq!(grants.len(), demands.len());
            for (g, d) in grants.iter().zip(&demands) {
                assert!(
                    *g <= d.demand_bytes,
                    "{policy:?} trial {trial}: grant {g} exceeds demand {}",
                    d.demand_bytes
                );
            }
            let total_demand: usize = demands.iter().map(|d| d.demand_bytes).sum();
            let granted: usize = grants.iter().sum();
            // work conservation: the arbiter hands out every byte it can
            assert_eq!(
                granted,
                global.min(total_demand),
                "{policy:?} trial {trial}: granted {granted} of budget {global}, \
                 demand {total_demand}"
            );
            // determinism: the same round arbitrates identically
            assert_eq!(arb.arbitrate(&demands), &grants[..]);
        }
    }
}

#[test]
fn unconstrained_rounds_grant_full_demand() {
    let mut rng = Lcg(0x5EED_0002);
    for policy in policies() {
        for _ in 0..100 {
            let n = 1 + rng.below(6) as usize;
            let demands: Vec<SessionDemand> = (0..n)
                .map(|_| SessionDemand {
                    demand_bytes: rng.below(64 * 1024) as usize,
                    mean_latency_ns: rng.below(4_000_000) as f64,
                })
                .collect();
            let total: usize = demands.iter().map(|d| d.demand_bytes).sum();
            // budget at least the total demand: nobody is cut
            let mut arb = PrefetchArbiter::new(policy, total + rng.below(4096) as usize);
            let grants = arb.arbitrate(&demands);
            let want: Vec<usize> = demands.iter().map(|d| d.demand_bytes).collect();
            assert_eq!(grants, &want[..], "{policy:?} cut an unconstrained round");
        }
    }
}

#[test]
fn fair_share_treats_identical_sessions_identically() {
    let mut rng = Lcg(0x5EED_0003);
    for _ in 0..200 {
        let n = 2 + rng.below(7) as usize;
        let demand = 1 + rng.below(128 * 1024) as usize;
        let global = rng.below(1 << 20) as usize;
        let demands =
            vec![SessionDemand { demand_bytes: demand, mean_latency_ns: 7e5 }; n];
        let mut arb = PrefetchArbiter::new(ArbiterPolicy::FairShare, global);
        let grants = arb.arbitrate(&demands);
        let (lo, hi) =
            (*grants.iter().min().unwrap(), *grants.iter().max().unwrap());
        assert!(
            hi - lo <= 1,
            "identical sessions diverged: {grants:?} (demand {demand}, \
             budget {global})"
        );
    }
}

#[test]
fn serve_attribution_sums_to_aggregate_totals() {
    // end-to-end: for several contention shapes, the per-session
    // hit/waste attribution must account for every speculated bundle
    // the aggregate metrics saw.
    let mut w = tiny_workload();
    w.eval_tokens = 8;
    w.prefetch.enabled = true;
    for (sessions, policy) in [
        (1, ArbiterPolicy::FairShare),
        (3, ArbiterPolicy::FairShare),
        (3, ArbiterPolicy::DeadlineAware { target_ns: 5e5 }),
    ] {
        let spec = SystemSpec::of(System::Ripple, w.model.ffn_linears);
        let cfg = ServeConfig { sessions, arbiter: policy, ..ServeConfig::default() };
        let out = run_serve(&w, System::Ripple, spec, &cfg).unwrap();
        assert_eq!(out.summary.session_prefetch.len(), sessions);
        let hit: u64 = out
            .summary
            .session_prefetch
            .iter()
            .map(|p| p.prefetch_hit_bundles)
            .sum();
        let waste: u64 = out
            .summary
            .session_prefetch
            .iter()
            .map(|p| p.prefetch_wasted_bundles)
            .sum();
        assert_eq!(hit, out.metrics.totals.prefetch_hit_bundles, "{policy:?}");
        assert_eq!(waste, out.metrics.totals.prefetch_wasted_bundles, "{policy:?}");
        let hit_bytes: u64 = out
            .summary
            .session_prefetch
            .iter()
            .map(|p| p.prefetch_hit_bytes)
            .sum();
        assert_eq!(
            hit_bytes,
            out.metrics.totals.prefetch_hit_bundles * out.bundle_bytes as u64,
            "{policy:?}"
        );
    }
}
