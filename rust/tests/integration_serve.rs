//! Acceptance tests for the multi-session serving simulation
//! (DESIGN.md §Serving) on the hot-overlap workload — statistically
//! identical sessions whose hot neuron sets coincide (same model
//! community structure, same dataset popularity, distinct streams):
//!
//! * the headline result: at equal TOTAL DRAM, one shared neuron cache
//!   achieves an aggregate hit ratio >= private per-session partitions,
//!   with cross-session reuse > 0, and aggregate e2e latency no worse;
//! * continuous batching: sessions join/leave between tokens, slots
//!   bound concurrency, queueing delay is observed and fairness stays
//!   reasonable;
//! * the whole serve path is deterministic run-to-run.

use ripple::bench::workloads::{tiny_workload, System, SystemSpec, Workload};
use ripple::coordinator::{run_serve, ServeConfig, ServeOutcome};

/// Hot-overlap serving workload: the tiny RIPPLE geometry on alpaca
/// (strongly clustered hot communities), deterministic s3fifo policy so
/// shared-vs-private differences come from sharing alone, not from the
/// linking admission's coin flips.
fn serve_workload() -> (Workload, SystemSpec) {
    let mut w = tiny_workload();
    w.eval_tokens = 24;
    let mut spec = SystemSpec::of(System::Ripple, w.model.ffn_linears);
    spec.cache_policy = "s3fifo";
    (w, spec)
}

fn run(shared: bool, sessions: usize) -> ServeOutcome {
    let (w, spec) = serve_workload();
    let cfg = ServeConfig {
        sessions,
        max_concurrent: sessions,
        arrival_spacing_ns: 0.0,
        shared_cache: shared,
        ..ServeConfig::default()
    };
    run_serve(&w, System::Ripple, spec, &cfg).unwrap()
}

/// Same hot-overlap workload with speculative prefetch enabled: every
/// session decodes on the overlapped flash timeline and the arbiter
/// splits the global speculative budget each round.
fn run_prefetch(sessions: usize, cfg_mut: impl FnOnce(&mut ServeConfig)) -> ServeOutcome {
    let (mut w, spec) = serve_workload();
    w.prefetch.enabled = true;
    let mut cfg = ServeConfig {
        sessions,
        max_concurrent: sessions,
        arrival_spacing_ns: 0.0,
        shared_cache: true,
        ..ServeConfig::default()
    };
    cfg_mut(&mut cfg);
    run_serve(&w, System::Ripple, spec, &cfg).unwrap()
}

#[test]
fn shared_cache_beats_private_partitions_at_equal_total_capacity() {
    let shared = run(true, 4);
    let private = run(false, 4);

    // both served the same total work
    assert_eq!(shared.metrics.tokens, 4 * 24);
    assert_eq!(private.metrics.tokens, 4 * 24);

    // headline: aggregate hit ratio of the shared cache >= the summed
    // private partitions, and the win is fed by cross-session reuse
    let h_shared = shared.metrics.cache_hit_ratio();
    let h_private = private.metrics.cache_hit_ratio();
    assert!(
        h_shared >= h_private,
        "shared hit ratio {h_shared:.4} < private {h_private:.4}"
    );
    assert!(
        shared.summary.cross_session_hit_ratio > 0.0,
        "hot-overlap sessions must reuse each other's admissions"
    );
    assert_eq!(private.summary.cross_session_hit_ratio, 0.0);

    // and e2e is no worse: more hits -> fewer flash reads on the shared
    // serial device (tiny tolerance for collapse-plan divergence)
    assert!(
        shared.summary.mean_ms <= private.summary.mean_ms * 1.02,
        "shared e2e {:.3}ms worse than private {:.3}ms",
        shared.summary.mean_ms,
        private.summary.mean_ms
    );
    // transferred volume tells the same story (small slack: the
    // adaptive collapse controller may fill gaps differently around a
    // different miss pattern)
    assert!(
        shared.metrics.totals.bytes <= private.metrics.totals.bytes * 102 / 100,
        "shared moved more bytes: {} vs {}",
        shared.metrics.totals.bytes,
        private.metrics.totals.bytes
    );
}

#[test]
fn continuous_batching_joins_and_leaves_between_tokens() {
    let (w, spec) = serve_workload();
    let cfg = ServeConfig {
        sessions: 5,
        max_concurrent: 2,
        // arrivals spread slightly so join order is exercised, but not
        // so far apart that the queue never forms
        arrival_spacing_ns: 1e5,
        shared_cache: true,
        ..ServeConfig::default()
    };
    let out = run_serve(&w, System::Ripple, spec, &cfg).unwrap();

    // slots bound concurrency; everyone eventually runs to completion
    assert!(out.serve.peak_active <= 2);
    assert_eq!(out.serve.sessions.len(), 5);
    for s in &out.serve.sessions {
        assert_eq!(s.tokens, 24, "session {} did not finish", s.id);
    }
    // later sessions queue behind the two slots
    assert!(out.serve.sessions[4].queue_delay_ns > 0.0);
    assert!(out.summary.mean_queue_delay_ms > 0.0);
    // sessions finish at different times (leave), so the last session's
    // completion defines the makespan
    let max_finish = out
        .serve
        .sessions
        .iter()
        .map(|s| s.finished_ns)
        .fold(0.0f64, f64::max);
    assert_eq!(max_finish.to_bits(), out.serve.makespan_ns.to_bits());
    // round-robin rotation keeps service roughly fair among sessions
    assert!(
        out.summary.fairness > 0.5,
        "fairness collapsed: {}",
        out.summary.fairness
    );
}

#[test]
fn serving_contention_raises_tail_latency() {
    let alone = run(true, 1);
    let packed = run(true, 4);
    // four sessions share one serial flash device: the tail must feel it
    assert!(
        packed.summary.p95_ms > alone.summary.p95_ms,
        "contention did not surface in the tail: {} vs {}",
        packed.summary.p95_ms,
        alone.summary.p95_ms
    );
    // and 4x the work costs about 4x the serial device time — shared
    // warmup amortizes over more tokens, capacity contention pushes the
    // other way; both effects are small next to the serial I/O
    assert!(
        packed.summary.makespan_ms < 4.2 * alone.summary.makespan_ms,
        "packed makespan {:.2}ms vs 4x alone {:.2}ms",
        packed.summary.makespan_ms,
        4.0 * alone.summary.makespan_ms
    );
}

#[test]
fn speculative_prefetch_improves_contended_serving() {
    let off = run(true, 4);
    let on = run_prefetch(4, |_| {});

    // same total work either way
    assert_eq!(on.metrics.tokens, off.metrics.tokens);

    // speculation hides flash reads under compute: mean and tail improve
    // under maximum contention (4 packed sessions, one serial device)
    assert!(
        on.summary.mean_ms < off.summary.mean_ms,
        "prefetch did not improve contended mean: {:.3} vs {:.3} ms",
        on.summary.mean_ms,
        off.summary.mean_ms
    );
    assert!(
        on.summary.p95_ms <= off.summary.p95_ms,
        "prefetch did not improve contended p95: {:.3} vs {:.3} ms",
        on.summary.p95_ms,
        off.summary.p95_ms
    );
    assert!(on.metrics.overlap_ratio() > 0.0);

    // attribution rides along: per-session rows exist and their bundle
    // counts sum to the aggregate totals
    assert_eq!(on.summary.session_prefetch.len(), 4);
    let hit: u64 = on.summary.session_prefetch.iter().map(|p| p.prefetch_hit_bundles).sum();
    let waste: u64 =
        on.summary.session_prefetch.iter().map(|p| p.prefetch_wasted_bundles).sum();
    assert_eq!(hit, on.metrics.totals.prefetch_hit_bundles);
    assert_eq!(waste, on.metrics.totals.prefetch_wasted_bundles);
    assert_eq!(hit, on.summary.prefetch_hit_bundles);
    assert_eq!(waste, on.summary.prefetch_wasted_bundles);
    assert!(hit > 0, "hot-overlap sessions must land speculative hits");

    // the prefetch-off summary carries no attribution (stable schema)
    assert!(off.summary.session_prefetch.is_empty());
    assert_eq!(off.summary.prefetch_hit_bundles, 0);
}

#[test]
fn zero_global_budget_disables_all_speculation() {
    let out = run_prefetch(3, |cfg| cfg.prefetch_global_budget = Some(0));
    // every round's grant is 0 bytes -> no speculative reads anywhere
    assert_eq!(out.metrics.totals.prefetch_hit_bundles, 0);
    assert_eq!(out.metrics.totals.prefetch_wasted_bundles, 0);
    assert_eq!(out.metrics.tokens, 3 * 24);
    // attribution rows still exist (the run was overlapped) but are empty
    assert_eq!(out.summary.session_prefetch.len(), 3);
    for p in &out.summary.session_prefetch {
        assert_eq!(p.prefetch_hit_bundles, 0);
        assert_eq!(p.prefetch_wasted_bundles, 0);
    }
}

#[test]
fn prefetch_serve_outcome_is_deterministic_run_to_run() {
    let a = run_prefetch(3, |_| {});
    let b = run_prefetch(3, |_| {});
    assert_eq!(
        a.metrics.totals.elapsed_ns.to_bits(),
        b.metrics.totals.elapsed_ns.to_bits()
    );
    assert_eq!(a.metrics.totals.bytes, b.metrics.totals.bytes);
    assert_eq!(a.metrics.totals.prefetch_hit_bundles, b.metrics.totals.prefetch_hit_bundles);
    assert_eq!(a.summary.p50_ms.to_bits(), b.summary.p50_ms.to_bits());
    assert_eq!(a.summary.makespan_ms.to_bits(), b.summary.makespan_ms.to_bits());
    assert_eq!(a.summary.session_prefetch, b.summary.session_prefetch);
}

#[test]
fn serve_outcome_is_deterministic_run_to_run() {
    let a = run(true, 3);
    let b = run(true, 3);
    assert_eq!(
        a.metrics.totals.elapsed_ns.to_bits(),
        b.metrics.totals.elapsed_ns.to_bits()
    );
    assert_eq!(a.metrics.totals.commands, b.metrics.totals.commands);
    assert_eq!(a.metrics.totals.bytes, b.metrics.totals.bytes);
    assert_eq!(a.summary.p50_ms.to_bits(), b.summary.p50_ms.to_bits());
    assert_eq!(a.summary.p99_ms.to_bits(), b.summary.p99_ms.to_bits());
    assert_eq!(a.summary.makespan_ms.to_bits(), b.summary.makespan_ms.to_bits());
    assert_eq!(
        a.summary.cross_session_hit_ratio.to_bits(),
        b.summary.cross_session_hit_ratio.to_bits()
    );
    for (sa, sb) in a.serve.sessions.iter().zip(&b.serve.sessions) {
        assert_eq!(sa.queue_delay_ns.to_bits(), sb.queue_delay_ns.to_bits());
        assert_eq!(sa.finished_ns.to_bits(), sb.finished_ns.to_bits());
    }
}
