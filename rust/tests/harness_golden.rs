//! Harness determinism + equivalence gates:
//!
//! * the sweep JSON for a fig10-shaped matrix is byte-identical across
//!   `--threads 1` and `--threads 8` (the golden determinism contract
//!   every later perf PR diffs against),
//! * a scenario spec reproduces `run_experiment`'s metrics bit-for-bit
//!   (so `bench --preset fig18` reports the same numbers as the
//!   historical `benches/fig18_overlap.rs` loops),
//! * the degenerate event-driven fleet (fixed spacing, FIFO, unbounded
//!   admission) reproduces the round-based `run_serve` path bit-for-bit
//!   — sync and speculative-prefetch variants — pinning the
//!   discrete-event scheduler to the serving simulator it generalizes,
//!   and
//! * a report round-trips through `Baseline` with zero deltas.

use ripple::bench::workloads::{bench_workload, run_experiment, System, SystemSpec};
use ripple::coordinator::{run_fleet, run_serve, FleetConfig, FleetScheduler, ServeConfig};
use ripple::harness::{
    preset, run_matrix, run_scenario, Baseline, FleetPoint, PrefetchPoint, ScenarioSpec,
    ServePoint,
};
use ripple::trace::{ArrivalProcess, DatasetProfile};

#[test]
fn fig10_json_byte_identical_across_thread_counts() {
    // the fig10 axes (datasets x systems), shrunk to test scale
    let mut m = preset("fig10").unwrap();
    m.models = vec!["OPT-350M".to_string()];
    m.scale_down(64, 16, 1, 8);
    let a = run_matrix(&m, 1).unwrap();
    let b = run_matrix(&m, 8).unwrap();
    let (ja, jb) = (a.json_string(), b.json_string());
    assert!(!ja.is_empty());
    assert_eq!(ja, jb, "sweep JSON must be byte-identical across thread counts");
    // schema sanity: stable top-level fields and per-scenario metrics
    assert!(ja.starts_with('{'));
    assert!(ja.contains("\"schema_version\":2"));
    assert!(ja.contains("\"name\":\"fig10\""));
    assert!(ja.contains("\"e2e_ms_per_token\""));
    assert!(ja.contains("\"overlap_ratio\""));
    assert_eq!(a.results.len(), 3 * 3);
}

#[test]
fn scenario_reproduces_fig18_bench_metrics() {
    // exactly the construction benches/fig18_overlap.rs used, shrunk
    // identically on both sides for test speed
    let mut w = bench_workload("OPT-350M", 0, DatasetProfile::alpaca());
    w.cache_ratio = 0.1;
    w.prefetch.enabled = true;
    w.prefetch.budget_bytes = 256 * 1024;
    w.calib_tokens = 96;
    w.eval_tokens = 24;
    w.sim_layers = 2;
    w.knn = 16;
    let direct = run_experiment(&w, System::Ripple).unwrap();

    let mut spec = ScenarioSpec::new("fig18-point", "OPT-350M", System::Ripple);
    spec.cache_ratio = 0.1;
    spec.prefetch = PrefetchPoint { enabled: true, budget_bytes: 256 * 1024, lookahead: 1 };
    spec.calib_tokens = 96;
    spec.eval_tokens = 24;
    spec.sim_layers = 2;
    spec.knn = 16;
    let via = run_scenario(&spec, w.threads).unwrap();

    assert_eq!(via.metrics.tokens, direct.metrics.tokens);
    assert_eq!(via.metrics.totals.commands, direct.metrics.totals.commands);
    assert_eq!(via.metrics.totals.bytes, direct.metrics.totals.bytes);
    assert_eq!(
        via.metrics.totals.prefetch_hit_bundles,
        direct.metrics.totals.prefetch_hit_bundles
    );
    assert_eq!(
        via.metrics.totals.elapsed_ns.to_bits(),
        direct.metrics.totals.elapsed_ns.to_bits()
    );
    assert_eq!(
        via.metrics.totals.stall_ns.to_bits(),
        direct.metrics.totals.stall_ns.to_bits()
    );
    assert_eq!(via.e2e_ms().to_bits(), direct.e2e_ms().to_bits());
    assert!(via.overlap_ratio() > 0.0, "fig18 point should overlap");
}

#[test]
fn serve_json_byte_identical_across_thread_counts() {
    // the serve axes (sessions x shared-vs-private), shrunk to test scale
    let mut m = preset("serve").unwrap();
    m.serve = vec![
        Some(ServePoint::shared(1)),
        Some(ServePoint::shared(3)),
        Some(ServePoint::private(3)),
    ];
    m.scale_down(48, 12, 2, 8);
    let a = run_matrix(&m, 1).unwrap();
    let b = run_matrix(&m, 8).unwrap();
    let (ja, jb) = (a.json_string(), b.json_string());
    assert_eq!(ja, jb, "serve JSON must be byte-identical across thread counts");
    assert!(ja.contains("\"name\":\"serve\""));
    assert!(ja.contains("\"serve_metrics\":{"));
    assert!(ja.contains("\"p99_ms\""));
    assert!(ja.contains("\"cross_session_hit_ratio\""));
    assert_eq!(a.results.len(), 3);
    // the markdown carries the serving section and the shared-vs-private
    // delta table for the paired 3-session points
    let md = a.to_markdown(None);
    assert!(md.contains("## Serving (multi-session)"), "{md}");
    assert!(md.contains("### Shared vs private cache"), "{md}");
}

#[test]
fn serve_single_session_reproduces_single_stream_metrics_bit_for_bit() {
    // the fig10 ripple/alpaca point, shrunk identically on both sides
    let mut plain = ScenarioSpec::new("plain", "OPT-350M", System::Ripple);
    plain.calib_tokens = 64;
    plain.eval_tokens = 16;
    plain.sim_layers = 2;
    plain.knn = 8;
    let direct = run_scenario(&plain, 2).unwrap();
    assert!(direct.serve.is_none());

    let mut via = plain.clone();
    via.name = "serve-anchor".to_string();
    via.serve = Some(ServePoint::shared(1));
    let served = run_scenario(&via, 2).unwrap();

    let (a, b) = (&direct.metrics, &served.metrics);
    assert_eq!(a.tokens, b.tokens);
    assert_eq!(a.totals.commands, b.totals.commands);
    assert_eq!(a.totals.bytes, b.totals.bytes);
    assert_eq!(a.totals.demanded_bundles, b.totals.demanded_bundles);
    assert_eq!(a.totals.cached_bundles, b.totals.cached_bundles);
    assert_eq!(a.totals.read_bundles, b.totals.read_bundles);
    assert_eq!(a.totals.extra_bundles, b.totals.extra_bundles);
    assert_eq!(a.totals.elapsed_ns.to_bits(), b.totals.elapsed_ns.to_bits());
    assert_eq!(a.totals.stall_ns.to_bits(), b.totals.stall_ns.to_bits());
    assert_eq!(a.compute_ns.to_bits(), b.compute_ns.to_bits());
    assert_eq!(direct.e2e_ms().to_bits(), served.e2e_ms().to_bits());
    assert_eq!(direct.latency_ms().to_bits(), served.latency_ms().to_bits());
    // and the serve summary is coherent with the single stream
    let sv = served.serve.expect("serve summary");
    assert_eq!(sv.sessions, 1);
    assert_eq!(sv.tokens, 16);
    assert_eq!(sv.cross_session_hit_ratio, 0.0, "one session cannot cross-hit");
    assert_eq!(sv.mean_queue_delay_ms, 0.0, "an idle server admits instantly");
}

#[test]
fn prefetch_serve_single_session_reproduces_overlapped_stream_bit_for_bit() {
    // the overlapped (speculative prefetch) single stream, shrunk
    // identically on both sides — sessions == 1 under the arbiter must
    // reduce to it exactly: one session's fair share IS the full budget
    let mut plain = ScenarioSpec::new("plain-pf", "OPT-350M", System::Ripple);
    plain.calib_tokens = 64;
    plain.eval_tokens = 16;
    plain.sim_layers = 2;
    plain.knn = 8;
    plain.prefetch = PrefetchPoint::budget_kb(64);
    let direct = run_scenario(&plain, 2).unwrap();
    assert!(direct.serve.is_none());
    assert!(direct.overlap_ratio() > 0.0, "the overlapped anchor must overlap");

    let mut via = plain.clone();
    via.name = "serve-pf-anchor".to_string();
    via.serve = Some(ServePoint::shared(1));
    let served = run_scenario(&via, 2).unwrap();

    let (a, b) = (&direct.metrics, &served.metrics);
    assert_eq!(a.tokens, b.tokens);
    assert_eq!(a.totals.commands, b.totals.commands);
    assert_eq!(a.totals.bytes, b.totals.bytes);
    assert_eq!(a.totals.demanded_bundles, b.totals.demanded_bundles);
    assert_eq!(a.totals.cached_bundles, b.totals.cached_bundles);
    assert_eq!(a.totals.read_bundles, b.totals.read_bundles);
    assert_eq!(a.totals.prefetch_hit_bundles, b.totals.prefetch_hit_bundles);
    assert_eq!(a.totals.prefetch_wasted_bundles, b.totals.prefetch_wasted_bundles);
    assert_eq!(a.totals.elapsed_ns.to_bits(), b.totals.elapsed_ns.to_bits());
    assert_eq!(a.totals.stall_ns.to_bits(), b.totals.stall_ns.to_bits());
    assert_eq!(a.compute_ns.to_bits(), b.compute_ns.to_bits());
    assert_eq!(direct.e2e_ms().to_bits(), served.e2e_ms().to_bits());
    assert_eq!(direct.latency_ms().to_bits(), served.latency_ms().to_bits());
    // the serve summary attributes the whole stream to session 0
    let sv = served.serve.expect("serve summary");
    assert_eq!(sv.sessions, 1);
    assert_eq!(sv.session_prefetch.len(), 1);
    assert_eq!(
        sv.session_prefetch[0].prefetch_hit_bundles,
        a.totals.prefetch_hit_bundles
    );
    assert_eq!(sv.prefetch_hit_bundles, a.totals.prefetch_hit_bundles);
}

#[test]
fn serve_prefetch_json_byte_identical_across_thread_counts() {
    // arbitrated serve rows, shrunk to test scale: the report (with the
    // attribution keys) must stay a pure function of the spec
    let mut m = preset("serve-prefetch").unwrap();
    m.prefetch = vec![PrefetchPoint::budget_kb(64)];
    m.serve = vec![
        Some(ServePoint::shared(2)),
        Some(
            ServePoint::shared(2)
                .with_arbiter(ripple::coordinator::ArbiterPolicy::DeadlineAware {
                    target_ns: 2e6,
                })
                .with_global_budget(96 * 1024),
        ),
    ];
    m.extra.clear();
    m.scale_down(48, 12, 2, 8);
    let a = run_matrix(&m, 1).unwrap();
    let b = run_matrix(&m, 8).unwrap();
    let (ja, jb) = (a.json_string(), b.json_string());
    assert_eq!(ja, jb, "serve-prefetch JSON must be byte-identical across threads");
    assert!(ja.contains("\"session_prefetch\":["));
    assert!(ja.contains("\"arbiter\":\"deadline\""));
    assert!(ja.contains("\"prefetch_global_budget_bytes\":98304"));
    assert!(ja.contains("\"mean_service_ms\""));
    assert_eq!(a.results.len(), 2);
}

/// The common shrink both sides of the fleet-vs-serve reductions use.
fn golden_fleet_workload() -> ripple::bench::workloads::Workload {
    let mut w = bench_workload("OPT-350M", 0, DatasetProfile::alpaca());
    w.calib_tokens = 64;
    w.eval_tokens = 16;
    w.sim_layers = 2;
    w.knn = 8;
    w
}

#[test]
fn fleet_degenerate_reduces_to_serve_bit_for_bit() {
    // fixed spacing + FIFO + unbounded admission + no SLO is exactly
    // the SessionManager serve shape: the event-driven scheduler must
    // replay its f64 operations in the same order
    let w = golden_fleet_workload();
    let spec = SystemSpec::of(System::Ripple, w.model.ffn_linears);
    let serve_cfg = ServeConfig {
        sessions: 3,
        max_concurrent: 2,
        arrival_spacing_ns: 40_000.0,
        ..ServeConfig::default()
    };
    let serve = run_serve(&w, System::Ripple, spec, &serve_cfg).unwrap();
    let fleet_cfg = FleetConfig {
        sessions: 3,
        max_concurrent: 2,
        arrival: ArrivalProcess::Fixed { spacing_ns: 40_000.0 },
        ..FleetConfig::default()
    };
    let fleet = run_fleet(&w, System::Ripple, spec, &fleet_cfg).unwrap();
    // the flat summary compares every f64; to_bits pins the tails even
    // against -0.0 == 0.0 laxity in PartialEq
    assert_eq!(fleet.summary, serve.summary);
    assert_eq!(fleet.summary.makespan_ms.to_bits(), serve.summary.makespan_ms.to_bits());
    assert_eq!(fleet.summary.p99_ms.to_bits(), serve.summary.p99_ms.to_bits());
    assert_eq!(fleet.summary.p999_ms.to_bits(), serve.summary.p999_ms.to_bits());
    assert_eq!(fleet.summary.mean_ms.to_bits(), serve.summary.mean_ms.to_bits());
    let (a, b) = (&serve.metrics, &fleet.metrics);
    assert_eq!(a.tokens, b.tokens);
    assert_eq!(a.totals.commands, b.totals.commands);
    assert_eq!(a.totals.bytes, b.totals.bytes);
    assert_eq!(a.totals.demanded_bundles, b.totals.demanded_bundles);
    assert_eq!(a.totals.cached_bundles, b.totals.cached_bundles);
    assert_eq!(a.totals.read_bundles, b.totals.read_bundles);
    assert_eq!(a.totals.elapsed_ns.to_bits(), b.totals.elapsed_ns.to_bits());
    assert_eq!(a.totals.stall_ns.to_bits(), b.totals.stall_ns.to_bits());
    assert_eq!(a.compute_ns.to_bits(), b.compute_ns.to_bits());
    assert_eq!(fleet.bundle_bytes, serve.bundle_bytes);
    // the open-loop accounting is trivial here: everything completes
    assert_eq!(fleet.fleet.rejected_sessions, 0);
    assert_eq!(fleet.fleet.completed_tokens, a.tokens);
    assert!(fleet.fleet.conserves_load());
}

#[test]
fn fleet_degenerate_prefetch_reduces_to_arbitrated_serve_bit_for_bit() {
    // the speculative variant: every session runs the overlapped
    // pipeline under the fair-share arbiter on both paths
    let mut w = golden_fleet_workload();
    w.prefetch.enabled = true;
    w.prefetch.budget_bytes = 64 * 1024;
    let spec = SystemSpec::of(System::Ripple, w.model.ffn_linears);
    let serve_cfg = ServeConfig { sessions: 2, max_concurrent: 2, ..ServeConfig::default() };
    let serve = run_serve(&w, System::Ripple, spec, &serve_cfg).unwrap();
    let fleet_cfg = FleetConfig { sessions: 2, max_concurrent: 2, ..FleetConfig::default() };
    let fleet = run_fleet(&w, System::Ripple, spec, &fleet_cfg).unwrap();
    // summary equality covers the per-session attribution rows too
    assert_eq!(fleet.summary, serve.summary);
    let (a, b) = (&serve.metrics, &fleet.metrics);
    assert_eq!(a.tokens, b.tokens);
    assert_eq!(a.totals.commands, b.totals.commands);
    assert_eq!(a.totals.bytes, b.totals.bytes);
    assert_eq!(a.totals.prefetch_hit_bundles, b.totals.prefetch_hit_bundles);
    assert_eq!(a.totals.prefetch_wasted_bundles, b.totals.prefetch_wasted_bundles);
    assert_eq!(a.totals.elapsed_ns.to_bits(), b.totals.elapsed_ns.to_bits());
    assert_eq!(a.totals.stall_ns.to_bits(), b.totals.stall_ns.to_bits());
    assert_eq!(a.compute_ns.to_bits(), b.compute_ns.to_bits());
    assert!(
        a.totals.prefetch_hit_bundles + a.totals.prefetch_wasted_bundles > 0,
        "the speculative anchor must actually speculate"
    );
}

#[test]
fn fleet_json_byte_identical_across_thread_counts() {
    // the open-loop axes shrunk to test scale: a degenerate anchor, a
    // two-rate Poisson ramp sharing one ramp key, and a bounded SRT row
    let mut m = preset("fleet").unwrap();
    m.extra.clear();
    m.fleet = vec![
        Some(FleetPoint::fixed(6, 0.0)),
        Some(FleetPoint::poisson(6, 400.0).with_slo_ms(40.0)),
        Some(FleetPoint::poisson(6, 1600.0).with_slo_ms(40.0)),
        Some(
            FleetPoint::poisson(6, 1600.0)
                .with_scheduler(FleetScheduler::ShortestRemaining)
                .with_bound(2)
                .with_slo_ms(40.0),
        ),
    ];
    m.scale_down(48, 4, 2, 8);
    let a = run_matrix(&m, 1).unwrap();
    let b = run_matrix(&m, 8).unwrap();
    let (ja, jb) = (a.json_string(), b.json_string());
    assert_eq!(ja, jb, "fleet JSON must be byte-identical across thread counts");
    assert!(ja.contains("\"name\":\"fleet\""));
    assert!(ja.contains("\"fleet\":{"));
    assert!(ja.contains("\"fleet_metrics\":{"));
    assert!(ja.contains("\"goodput_tokens_per_s\""));
    assert!(ja.contains("\"p999_ms\""));
    assert!(ja.contains("\"slo_violation_rate\""));
    assert!(ja.contains("\"arrival\":\"po400\""));
    assert_eq!(a.results.len(), 4);
    // reruns are byte-identical too (the BENCH_fleet.json contract)
    let again = run_matrix(&m, 8).unwrap();
    assert_eq!(ja, again.json_string());
    let md = a.to_markdown(None);
    assert!(md.contains("## Fleet (open-loop, event-driven)"), "{md}");
    assert!(md.contains("### Load ramp `f6c4-fifo-slo40ms`"), "{md}");
}

/// The common shrink for the cache-lab pins below.
fn cachelab_spec(name: &str, policy: &str, ratio: f64) -> ScenarioSpec {
    let mut spec = ScenarioSpec::new(name, "OPT-350M", System::Ripple);
    spec.cache_ratio = ratio;
    spec.cache_policy = Some(policy.to_string());
    spec.calib_tokens = 96;
    spec.eval_tokens = 24;
    spec.sim_layers = 2;
    spec.knn = 16;
    spec
}

/// The cachelab headline pin (ISSUE 9): at matched DRAM budgets,
/// flash-cost-aware eviction must not lose to plain LRU end to end —
/// cheap linked-run keys leave first, so the misses that remain are the
/// ones that amortize into fewer flash commands. The margin moves with
/// cache geometry, so the pin quantifies over the pressured fig14
/// ratios: cost-aware must meet-or-beat LRU somewhere on the sweep, and
/// both rows must agree on the work done (same tokens, same demanded
/// bundles — "equal DRAM" means only the eviction choice differs).
#[test]
fn cachelab_costaware_meets_lru_end_to_end_at_equal_dram() {
    let mut met_or_beat = 0usize;
    for ratio in [0.05, 0.1, 0.2, 0.3, 0.4] {
        let lru = run_scenario(&cachelab_spec("pin-lru", "lru", ratio), 2).unwrap();
        let ca =
            run_scenario(&cachelab_spec("pin-costaware", "costaware", ratio), 2).unwrap();
        assert_eq!(lru.metrics.tokens, ca.metrics.tokens, "ratio {ratio}");
        assert_eq!(
            lru.metrics.totals.demanded_bundles, ca.metrics.totals.demanded_bundles,
            "equal DRAM rows must demand the same bundles (ratio {ratio})"
        );
        if ca.e2e_ms() <= lru.e2e_ms() {
            met_or_beat += 1;
        }
    }
    assert!(
        met_or_beat > 0,
        "cost-aware eviction lost to LRU at every pressured cache ratio"
    );
}

/// The stats-reset regression (ISSUE 9): two back-to-back rows with the
/// same spec must report the same `cache_hit_ratio` bit for bit — no
/// counter state may bleed from one row into the next, whatever the
/// policy. Runs the full policy roster so a future runner that reuses a
/// cache (or an engine) across rows trips this immediately.
#[test]
fn back_to_back_identical_rows_report_identical_cache_hit_ratios() {
    for policy in ["linking", "lru", "victim", "setassoc", "costaware"] {
        let first = run_scenario(&cachelab_spec("row-a", policy, 0.1), 2).unwrap();
        let second = run_scenario(&cachelab_spec("row-b", policy, 0.1), 2).unwrap();
        assert_eq!(
            first.metrics.cache_hit_ratio().to_bits(),
            second.metrics.cache_hit_ratio().to_bits(),
            "`{policy}`: back-to-back hit ratios diverged"
        );
        assert_eq!(
            first.metrics.totals.cached_bundles, second.metrics.totals.cached_bundles,
            "`{policy}`"
        );
        assert_eq!(
            first.metrics.totals.demanded_bundles, second.metrics.totals.demanded_bundles,
            "`{policy}`"
        );
        assert_eq!(
            first.e2e_ms().to_bits(),
            second.e2e_ms().to_bits(),
            "`{policy}`: back-to-back e2e diverged"
        );
    }
}

#[test]
fn smoke_report_baselines_against_itself_with_zero_deltas() {
    let mut m = preset("smoke").unwrap();
    m.models = vec!["opt-micro".to_string()];
    m.scale_down(64, 16, 2, 8);
    let report = run_matrix(&m, 4).unwrap();
    let base = Baseline::parse(&report.json_string()).unwrap();
    assert_eq!(base.len(), report.results.len());
    let md = report.to_markdown(Some(&base));
    assert!(md.contains("# BENCH smoke"));
    assert!(md.contains("vs baseline"));
    assert!(md.contains("+0.0%"), "self-baseline must show zero deltas:\n{md}");
    assert!(!md.contains("had no match"));
    // every scenario row made it into the table
    for r in &report.results {
        assert!(md.contains(&r.spec.name), "missing row for {}", r.spec.name);
    }
}
