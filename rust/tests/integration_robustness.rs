//! Robustness: failure injection, config validation, and randomized
//! cross-module property sweeps that don't fit a single unit scope.

use ripple::bench::workloads::{run_experiment, tiny_workload, System};
use ripple::cache::{CachePolicy, KeySpace, Lru, NeuronCache, S3Fifo};
use ripple::config::RunConfig;
use ripple::engine::{Engine, EngineOptions};
use ripple::neuron::Layout;
use ripple::util::prop;
use ripple::util::rng::Rng;

#[test]
fn engine_fails_cleanly_without_artifacts() {
    let err = Engine::load("/definitely/not/here", EngineOptions::default())
        .err()
        .expect("must fail");
    assert!(format!("{err:#}").contains("make artifacts"));
}

#[test]
fn engine_rejects_uncompiled_batch_size() {
    let dir = ripple::runtime::default_artifacts_dir();
    if !ripple::runtime::artifacts_available(&dir) {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let err = Engine::load(&dir, EngineOptions { batch: 3, ..Default::default() })
        .err()
        .expect("batch 3 is not a compiled variant");
    assert!(format!("{err:#}").contains("batch"));
}

#[test]
fn run_config_validation() {
    assert!(RunConfig::from_json_str("{").is_err());
    assert!(RunConfig::from_json_str(r#"{"model": 42}"#).is_ok()); // non-string ignored
    assert!(RunConfig::from_json_str(r#"{"model": "nope"}"#).is_err());
    assert!(RunConfig::from_json_str(r#"{"precision": "fp4"}"#).is_err());
    assert!(RunConfig::from_json_str(r#"{"cache_ratio": -0.1}"#).is_err());
    let ok = RunConfig::from_json_str(r#"{"model": "Mistral-7B", "cache_ratio": 0.3}"#).unwrap();
    assert_eq!(ok.model.name, "Mistral-7B");
}

#[test]
fn layout_rejects_corrupt_orders() {
    assert!(Layout::from_order(&[]).is_ok()); // empty is a valid (empty) layout
    assert!(Layout::from_order(&[1]).is_err()); // out of range
    assert!(Layout::from_order(&[0, 0]).is_err()); // duplicate
}

/// Both cache policies never exceed capacity and never "hit" a key that
/// was never inserted, under adversarial mixed workloads.
#[test]
fn prop_cache_policies_sound() {
    for policy in ["lru", "s3fifo"] {
        prop::run(
            &format!("cache-sound-{policy}"),
            prop::Config { cases: 40, max_size: 200, ..Default::default() },
            |rng: &mut Rng, size| {
                let cap = rng.range(1, 32);
                let ops: Vec<(bool, u64)> = (0..size * 4)
                    .map(|_| (rng.chance(0.5), rng.below(64) as u64))
                    .collect();
                (cap, ops)
            },
            |(cap, ops)| {
                let mut c: Box<dyn CachePolicy> = if *cap % 2 == 0 {
                    Box::new(Lru::new(*cap))
                } else {
                    Box::new(S3Fifo::new(*cap))
                };
                let mut inserted = std::collections::HashSet::new();
                for &(is_insert, key) in ops {
                    if is_insert {
                        c.insert(key);
                        inserted.insert(key);
                    } else {
                        let hit = c.touch(key);
                        if hit && !inserted.contains(&key) {
                            return Err(format!("hit on never-inserted key {key}"));
                        }
                    }
                    if c.len() > *cap {
                        return Err(format!("len {} > cap {cap}", c.len()));
                    }
                }
                Ok(())
            },
        );
    }
}

/// The experiment runner is total over every (system, precision,
/// cache-ratio) combination on a small workload — no panics, metrics
/// internally consistent.
#[test]
fn prop_experiment_runner_total() {
    use ripple::config::Precision;
    let mut w = tiny_workload();
    w.eval_tokens = 10;
    w.calib_tokens = 48;
    for system in System::all() {
        for prec in [Precision::Fp32, Precision::Fp16, Precision::Int8] {
            for ratio in [0.0, 0.1, 0.5] {
                w.precision = prec;
                w.cache_ratio = ratio;
                let r = run_experiment(&w, system).unwrap();
                let m = &r.metrics;
                assert_eq!(m.tokens, 10);
                assert!(m.totals.read_bundles >= m.totals.extra_bundles);
                assert!(
                    m.totals.bytes
                        >= m.totals.read_bundles * (r.bundle_bytes as u64 / 2),
                    "bytes vs bundles inconsistent"
                );
                if m.totals.commands > 0 {
                    assert!(m.mean_access_len() >= 1.0);
                }
            }
        }
    }
}

/// NeuronCache filter/admit stays consistent with an oracle hash map.
#[test]
fn prop_neuron_cache_matches_oracle_membership() {
    prop::run(
        "neuron-cache-oracle",
        prop::Config { cases: 30, max_size: 100, ..Default::default() },
        |rng: &mut Rng, size| {
            let tokens: Vec<Vec<u32>> = (0..size.max(2))
                .map(|_| {
                    let k = rng.range(1, 12);
                    let mut v: Vec<u32> = rng
                        .sample_indices(64, k)
                        .into_iter()
                        .map(|x| x as u32)
                        .collect();
                    v.sort_unstable();
                    v
                })
                .collect();
            tokens
        },
        |tokens| {
            // capacity larger than universe: nothing ever evicts, so the
            // cache must behave exactly like a set
            let mut c =
                NeuronCache::from_config("s3fifo", 1024, KeySpace::new(1, 64), 9).unwrap();
            let mut oracle = std::collections::HashSet::new();
            for tok in tokens {
                let (hits, misses) = c.filter(0, tok);
                for h in &hits {
                    if !oracle.contains(h) {
                        return Err(format!("false hit {h}"));
                    }
                }
                for m in &misses {
                    if oracle.contains(m) {
                        return Err(format!("false miss {m}"));
                    }
                }
                let runs = ripple::access::plan_runs(&misses);
                c.admit(0, &runs);
                oracle.extend(misses);
            }
            Ok(())
        },
    );
}
